"""Compiled execution engine: table-level and cache-level unit tests.

Device-level bit-identity against the pre-PR interpreter (4 collectives ×
{ring, rhd, dex, direct} × n ∈ {4, 8}, full-axis and split) runs in
exec_engine_check.py under 8 host devices in a subprocess — XLA locks the
device count at first init, so it cannot share this process.  Everything
here is device-free: fingerprints, compiled tables vs the per-round
reference, round-group folding, the slot-addressed all-to-all compile
(checked by a pure-numpy emulation of the executor), LRU accounting, and
the attributable trace-time errors.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.comm import exec_engine
from repro.comm.errors import ScheduleExecutionError
from repro.core import schedules as S
from repro.core.schedules import Round, Schedule, Transfer

ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------------ fingerprint
def test_fingerprint_stable_across_reconstruction():
    a = S.ring_reduce_scatter(8, 4096.0)
    b = S.ring_reduce_scatter(8, 4096.0)
    assert a is not b and a.fingerprint() == b.fingerprint()
    assert a.fingerprint() == a.fingerprint()  # memoized path


def test_fingerprint_ignores_byte_sizes():
    # a buffer-size sweep rescales one template; execution is unchanged, so
    # every size shares one compiled executable
    assert (
        S.ring_reduce_scatter(8, 1024.0).fingerprint()
        == S.ring_reduce_scatter(8, 1 << 30).fingerprint()
    )


def test_fingerprint_distinguishes_structure():
    fps = {
        S.ring_reduce_scatter(8, 1024.0).fingerprint(),
        S.rhd_reduce_scatter(8, 1024.0).fingerprint(),
        S.ring_all_gather(8, 1024.0).fingerprint(),
        S.ring_reduce_scatter(4, 1024.0).fingerprint(),
        S.dex_all_to_all(8, 1024.0).fingerprint(),
        S.direct_all_to_all(8, 1024.0).fingerprint(),
    }
    assert len(fps) == 6


def test_fingerprint_collision_regression():
    """Fingerprints are injective on schedule structure (the docstring of
    Schedule.fingerprint points here).  Sweep the generator zoo plus a
    batch of structurally-adjacent hand variants — every distinct
    (perm, chunk, reduce, round-boundary) table must hash distinctly."""
    zoo = []
    for n in (2, 3, 4, 6, 8, 16):
        zoo += [S.ring_reduce_scatter(n, 1.0), S.ring_all_gather(n, 1.0),
                S.ring_all_reduce(n, 1.0), S.direct_all_to_all(n, 1.0),
                S.ring_all_to_all(n, 1.0)]
    for n in (2, 4, 8, 16):
        zoo += [S.rhd_reduce_scatter(n, 1.0), S.rhd_all_gather(n, 1.0),
                S.rhd_all_reduce(n, 1.0), S.dex_all_to_all(n, 1.0)]
    for dims in ((2, 2), (2, 4), (3, 3), (2, 2, 2)):
        zoo += [S.bucket_reduce_scatter(dims, 1.0),
                S.bucket_all_gather(dims, 1.0)]

    # adjacent variants that a sloppy (non-delimited) encoding would merge:
    base = S.ring_reduce_scatter(4, 1.0)
    flat = Schedule(base.collective, base.algorithm, base.n, 1.0,
                    (Round(tuple(t for r in base.rounds
                                 for t in r.transfers), 1.0),))
    zoo.append(flat)  # same transfers, different round boundaries
    t = base.rounds[0].transfers[0]
    one = Schedule("p2p", "direct", 4, 1.0,
                   (Round((Transfer(t.src, t.dst, t.chunks, t.reduce),), 1.0),))
    two = Schedule("p2p", "direct", 4, 1.0,
                   (Round((Transfer(t.src, t.dst, (1, 2), t.reduce),), 1.0),))
    twelve = Schedule("p2p", "direct", 4, 1.0,
                      (Round((Transfer(t.src, t.dst, (12,), t.reduce),), 1.0),))
    zoo += [one, two, twelve]  # chunks (1,2) vs (12) must not collide

    fps = [s.fingerprint() for s in zoo]
    assert len(set(fps)) == len(fps), "fingerprint collision in sweep"


# ------------------------------------------------------------ PCCL_VERIFY
def _corrupt(sched):
    """Relabel one chunk: the rounds stay valid permutations (so the
    executable compiles), but the dataflow postcondition fails — exactly
    the class of bug only the static verifier catches."""
    rounds = list(sched.rounds)
    tf = list(rounds[0].transfers)
    t = tf[0]
    bad_chunk = (t.chunks[0] + 1) % sched.n
    tf[0] = Transfer(t.src, t.dst, (bad_chunk,) + t.chunks[1:], t.reduce)
    rounds[0] = Round(tuple(tf), rounds[0].size)
    return Schedule(sched.collective, sched.algorithm, sched.n,
                    sched.buffer_bytes, tuple(rounds))


def test_pccl_verify_disabled_compiles_corrupt(monkeypatch):
    monkeypatch.delenv("PCCL_VERIFY", raising=False)
    exec_engine.clear_exec_caches()
    compiled = exec_engine.compile_schedule(_corrupt(S.ring_reduce_scatter(8, 64.0)))
    assert compiled is not None  # off by default: zero-overhead path


def test_pccl_verify_enabled_rejects_corrupt(monkeypatch):
    from repro.analysis.verify import ScheduleVerificationError

    monkeypatch.setenv("PCCL_VERIFY", "1")
    exec_engine.clear_exec_caches()
    with pytest.raises(ScheduleVerificationError):
        exec_engine.compile_schedule(_corrupt(S.ring_reduce_scatter(8, 64.0)))
    # correct schedules still compile with verification on
    assert exec_engine.compile_schedule(S.ring_reduce_scatter(8, 64.0))


def test_pccl_verify_cache_hits_skip_verification(monkeypatch):
    monkeypatch.delenv("PCCL_VERIFY", raising=False)
    exec_engine.clear_exec_caches()
    bad = _corrupt(S.ring_reduce_scatter(8, 64.0))
    exec_engine.compile_schedule(bad)  # populate cache while disabled
    monkeypatch.setenv("PCCL_VERIFY", "1")
    # hit: env is only consulted on compile-cache miss
    assert exec_engine.compile_schedule(bad) is not None
    exec_engine.clear_exec_caches()


# -------------------------------------------------------- compiled tables
def _flat_tables(compiled):
    """(perm, send_row, recv_row, reduce) per round, unstacked."""
    out = []
    for grp in compiled.groups:
        for g in range(grp.rounds):
            out.append((list(grp.perm), grp.send_ids[g], grp.recv_ids[g], grp.reduce))
    return out


@pytest.mark.parametrize(
    "sched",
    [
        S.ring_reduce_scatter(8, 4096.0),
        S.rhd_all_gather(8, 4096.0),
        S.ring_all_reduce(8, 4096.0),
        S.bucket_all_reduce((2, 4), 4096.0),
        S.dex_all_to_all(8, 4096.0),
        S.direct_all_to_all(8, 4096.0),
        S.ring_all_to_all(4, 4096.0),
    ],
    ids=lambda s: f"{s.collective}-{s.algorithm}",
)
def test_compiled_tables_match_reference(sched):
    compiled = exec_engine.compile_schedule(sched)
    assert compiled.num_rounds == sched.num_rounds
    flat = _flat_tables(compiled)
    assert len(flat) == sched.num_rounds
    for i, rnd in enumerate(sched.rounds):
        perm, send, recv, reduce = exec_engine.round_tables(rnd, sched.n)
        cperm, csend, crecv, creduce = flat[i]
        assert cperm == perm and creduce == reduce
        np.testing.assert_array_equal(csend, send)
        np.testing.assert_array_equal(crecv, recv)


def test_round_group_folding():
    # ring RS: n-1 rounds, one perm, one reduce flag -> a single scan group
    rs = exec_engine.compile_schedule(S.ring_reduce_scatter(8, 1.0))
    assert [g.rounds for g in rs.groups] == [7]
    # ring all-reduce: RS phase + AG phase -> exactly two groups
    ar = exec_engine.compile_schedule(S.ring_all_reduce(8, 1.0))
    assert [g.rounds for g in ar.groups] == [7, 7]
    assert [g.reduce for g in ar.groups] == [True, False]
    # RHD pairs a different bit each round -> per-round fallback groups
    rhd = exec_engine.compile_schedule(S.rhd_reduce_scatter(8, 1.0))
    assert [g.rounds for g in rhd.groups] == [1, 1, 1]
    # bucket: every torus-axis phase folds into one group
    b = exec_engine.compile_schedule(S.bucket_reduce_scatter((2, 4), 1.0))
    assert sum(g.rounds for g in b.groups) == b.num_rounds
    assert len(b.groups) < b.num_rounds
    # ring all-to-all shares the perm but k shrinks per round -> no folding
    ra = exec_engine.compile_schedule(S.ring_all_to_all(4, 1.0))
    assert [g.rounds for g in ra.groups] == [1] * ra.num_rounds


def test_compiled_cache_accounting():
    exec_engine.clear_exec_caches()
    sched = S.ring_reduce_scatter(16, 512.0)
    c1 = exec_engine.compile_schedule(sched)
    s = exec_engine.exec_stats()
    assert s.compiled_misses == 1 and s.compiled_hits == 0
    c2 = exec_engine.compile_schedule(S.ring_reduce_scatter(16, 512.0))
    s = exec_engine.exec_stats()
    assert s.compiled_hits == 1 and c2 is c1  # the cached object, same id
    # a rescaled sweep template is the same executable (size-free fingerprint)
    c3 = exec_engine.compile_schedule(S.ring_reduce_scatter(16, 2048.0))
    assert c3 is c1


def test_lru_bound_and_eviction():
    lru = exec_engine._LruCache(max_entries=2)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1  # refreshes a
    lru.put("c", 3)  # evicts b (LRU)
    assert lru.get("b") is None and lru.get("a") == 1 and lru.get("c") == 3
    assert lru.evictions == 1 and len(lru) == 2


# ----------------------------------------------- slot-addressed all-to-all
def _emulate_compiled(compiled, m, local_of):
    """Pure-numpy replay of execute_compiled over integer chunk ids."""
    n_rows = compiled.n
    buf = np.array(
        [[local_of[r] * m + t for t in range(m)] for r in range(n_rows)],
        dtype=np.int64,
    )
    for grp in compiled.groups:
        dst_of = dict(grp.perm)
        for g in range(grp.rounds):
            payload = {r: buf[r, grp.send_ids[g, r]].copy() for r in range(n_rows)}
            for r in range(n_rows):
                d = dst_of[r]
                buf[d, grp.recv_ids[g, d]] = payload[r]
    return buf


@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("algo", ["dex", "direct", "ring"])
def test_slot_compile_satisfies_post_condition(n, algo):
    sched = S.get_schedule("all_to_all", algo, n, 4096.0)
    local_of = tuple(range(n))
    compiled = exec_engine.compile_all_to_all(sched, n, local_of)
    assert compiled is not None, f"{algo} n={n} must be slot-addressable"
    assert compiled.final_slots.shape == (n, n)
    buf = _emulate_compiled(compiled, n, local_of)
    # rank r ends holding block (o -> r) at final_slots[r, o], for every o
    for r in range(n):
        for o in range(n):
            assert buf[r, compiled.final_slots[r, o]] == o * n + r


def test_slot_compile_grouped_local_ids():
    """Composed split schedule: group-local chunk ids, global ranks."""
    from repro.api import subgroup_schedule

    m, n_axis = 4, 8
    groups = ((0, 2, 4, 6), (1, 3, 5, 7))
    local_of = [0] * n_axis
    for g in groups:
        for i, r in enumerate(g):
            local_of[r] = i
    sched = subgroup_schedule(S.direct_all_to_all(m, 1024.0), groups, n_axis)
    compiled = exec_engine.compile_all_to_all(sched, m, tuple(local_of))
    assert compiled is not None and compiled.final_slots.shape == (n_axis, m)
    buf = _emulate_compiled(compiled, m, tuple(local_of))
    for r in range(n_axis):
        for o in range(m):
            assert buf[r, compiled.final_slots[r, o]] == o * m + local_of[r]


def test_slot_compile_rejects_unheld_chunk():
    # rank 0 claims to send block (1 -> 1), which it never held -> dense path
    n = 2
    rounds = (
        Round(
            (
                Transfer(0, 1, chunks=(3,), reduce=False),
                Transfer(1, 0, chunks=(2,), reduce=False),
            ),
            1.0,
        ),
    )
    bad = Schedule("all_to_all", "bad", n, 4.0, rounds)
    assert exec_engine.compile_all_to_all(bad, n, (0, 1)) is None
    # the verdict (and the sentinel) is memoized
    assert exec_engine.compile_all_to_all(bad, n, (0, 1)) is None


def test_slot_compile_rejects_reduce_rounds():
    bad = Schedule(
        "all_to_all",
        "bad",
        2,
        4.0,
        (
            Round(
                (
                    Transfer(0, 1, chunks=(1,), reduce=True),
                    Transfer(1, 0, chunks=(2,), reduce=True),
                ),
                1.0,
            ),
        ),
    )
    assert exec_engine.compile_all_to_all(bad, 2, (0, 1)) is None


# ------------------------------------------------------ attributable errors
def test_round_table_errors_name_round_and_schedule():
    good = S.ring_all_gather(4, 1024.0)
    # break round 1: rank 0 sends twice (not a permutation)
    r1 = good.rounds[1]
    broken = Round(r1.transfers + (Transfer(0, 2, chunks=(0,)),), r1.size)
    bad = Schedule(
        good.collective, good.algorithm, good.n, good.buffer_bytes,
        (good.rounds[0], broken, good.rounds[2]),
    )
    with pytest.raises(ScheduleExecutionError, match=r"all_gather/ring round 1/3"):
        exec_engine.compile_schedule(bad)

    # chunkless schedules stay attributable too
    swing = S.swing_reduce_scatter(8, 1024.0)
    with pytest.raises(
        ScheduleExecutionError, match=r"reduce_scatter/swing round 0/3.*chunk"
    ):
        exec_engine.compile_schedule(swing)


def test_legacy_round_tables_signature():
    from repro.comm import primitives as prim

    rnd = S.ring_all_gather(4, 64.0).rounds[0]
    perm, send, recv, reduce = prim._round_tables(rnd, 4)
    assert len(perm) == 4 and send.shape == (4, 1) and reduce is False


# ------------------------------------------------------ communicator bits
def test_local_index_table_cached_and_correct():
    from repro.api import PcclSession
    from repro.core import cost_model as cm

    session = PcclSession(cm.H100_DGX, thread_fabric=False)
    root = session.communicator("x", 8, backend="sim")
    sub = root.split([r % 2 for r in range(8)])
    t1 = sub.local_index_table()
    np.testing.assert_array_equal(t1, [0, 0, 1, 1, 2, 2, 3, 3])
    assert sub.local_index_table() is t1  # built once, cached
    assert not t1.flags.writeable
    np.testing.assert_array_equal(root.local_index_table(), np.arange(8))
    assert root.group_fingerprint() == ("full", 8)
    assert sub.group_fingerprint() == ("split", ((0, 2, 4, 6), (1, 3, 5, 7)))


def test_sim_all_gather_preserves_array_namespace():
    from repro.api import PcclSession
    from repro.core import cost_model as cm

    session = PcclSession(cm.H100_DGX, thread_fabric=False)
    comm = session.communicator("x", 4, backend="sim")
    xnp = np.ones((2, 3), np.float16)
    out = comm.all_gather(xnp)
    assert isinstance(out, np.ndarray) and out.dtype == np.float16
    assert out.shape == (8, 3)

    jnp = pytest.importorskip("jax.numpy")
    xj = jnp.ones((2, 3), jnp.bfloat16)
    outj = comm.all_gather(xj)
    assert not isinstance(outj, np.ndarray)  # stayed a jax array
    assert outj.dtype == jnp.bfloat16 and outj.shape == (8, 3)


def test_session_exec_stats_surface():
    from repro.api import PcclSession
    from repro.core import cost_model as cm

    exec_engine.clear_exec_caches()
    s = PcclSession(cm.H100_DGX, thread_fabric=False)
    stats = s.exec_stats()
    assert stats.executable_hits == 0 and stats.traces == 0
    exec_engine.compile_schedule(S.ring_all_gather(4, 64.0))
    assert s.exec_stats().compiled_misses == 1


# ------------------------------------------------------- device subprocess
@pytest.mark.slow
@pytest.mark.multidevice
def test_exec_engine_device_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "exec_engine_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-EXEC-ENGINE-OK" in proc.stdout
