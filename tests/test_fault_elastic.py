"""Elastic scaling: mesh shrink + live re-shard, in an 8-device subprocess."""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
@pytest.mark.multidevice
def test_elastic_shrink_and_reshard():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "elastic_check.py")],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ELASTIC-OK" in proc.stdout
