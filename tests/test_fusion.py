"""Comm/compute fusion layer: stream programs, counters, ring_ef8 gating.

Single-device tests for :mod:`repro.comm.fusion` and its planner/engine
integration; the multi-device bit-identity checks (fused matmul+RS and
AR+rmsnorm vs the unfused oracle, quantized execution) run in
``fusion_check.py`` under 8 host devices in a subprocess — XLA locks the
device count at first jax init, so they cannot share this process.
"""

import os
import subprocess
import sys
import types
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))  # benchmarks/ is a root-level namespace pkg

from repro.comm import exec_engine
from repro.comm.fusion import _stream_program, stream_program
from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core.cost_model import compressed_ef_error_bound
from repro.core.pccl import candidate_algorithms

_D = float(1 << 20)


# ------------------------------------------------------- stream programs
class TestStreamProgram:
    @pytest.mark.parametrize("n", [2, 4, 8, 16])
    def test_ring_reduce_scatter_is_streamable(self, n):
        compiled = exec_engine.compile_schedule(S.ring_reduce_scatter(n, _D))
        prog = stream_program(compiled)
        assert prog is not None
        assert prog.rounds == n - 1
        assert prog.order.shape == (n, n)
        for r in range(n):
            # each rank's order is a permutation of the chunk ids …
            assert sorted(prog.order[r]) == list(range(n))
            # … in which every chunk a round touches is already computed:
            # round t runs at scan step t+1, after tiles order[: t+2]
            for t in range(prog.rounds):
                avail = set(prog.order[r][: t + 2].tolist())
                assert prog.send[t, r] in avail
                assert prog.recv[t, r] in avail

    def test_memoized_by_fingerprint(self):
        c1 = exec_engine.compile_schedule(S.ring_reduce_scatter(8, _D))
        c2 = exec_engine.compile_schedule(S.ring_reduce_scatter(8, 2 * _D))
        assert stream_program(c1) is stream_program(c2)  # same fingerprint

    @pytest.mark.parametrize(
        "sched",
        [
            S.ring_all_gather(8, _D),      # no reduction
            S.ring_all_reduce(8, _D),      # two phases -> two round groups
            S.rhd_reduce_scatter(8, _D),   # log-n rounds != n_chunks - 1
        ],
        ids=["all_gather", "all_reduce", "rhd"],
    )
    def test_non_streamable_schedules(self, sched):
        assert stream_program(exec_engine.compile_schedule(sched)) is None

    def test_infeasible_deadlines_rejected(self):
        # n=4, k=1, 3 rounds: every rank touches chunks {0,1} in round 0 and
        # {2,3} in round 1 -> 4 distinct chunks due by end of round 1, but
        # the scan has only produced 3 tiles by then (prologue + 2 steps)
        n, rounds = 4, 3
        send = np.array([[0] * n, [2] * n, [1] * n], dtype=np.int32)
        recv = np.array([[1] * n, [3] * n, [2] * n], dtype=np.int32)
        grp = types.SimpleNamespace(
            perm=tuple((i, (i + 1) % n) for i in range(n)),
            reduce=True,
            send_ids=send[:, :, None],
            recv_ids=recv[:, :, None],
        )
        fake = types.SimpleNamespace(groups=(grp,))
        assert _stream_program(fake) is None


# ---------------------------------------------------- overlap accounting
class TestExecStatsOverlap:
    def test_counters_accumulate_and_reset(self):
        exec_engine.clear_exec_caches()
        s0 = exec_engine.exec_stats()
        assert (s0.fused_dispatches, s0.fallback_dispatches) == (0, 0)
        assert (s0.chunks_streamed, s0.bytes_hidden) == (0, 0)
        exec_engine.note_fused_dispatch(chunks_streamed=8, bytes_hidden=4096)
        exec_engine.note_fused_dispatch(chunks_streamed=4, bytes_hidden=100)
        exec_engine.note_fallback_dispatch()
        s1 = exec_engine.exec_stats()
        assert s1.fused_dispatches == 2
        assert s1.fallback_dispatches == 1
        assert s1.chunks_streamed == 12
        assert s1.bytes_hidden == 4196
        exec_engine.clear_exec_caches()
        s2 = exec_engine.exec_stats()
        assert (s2.fused_dispatches, s2.fallback_dispatches) == (0, 0)
        assert (s2.chunks_streamed, s2.bytes_hidden) == (0, 0)

    def test_clear_exec_caches_drops_kernel_verify_memo(self):
        # regression (PR 9): PCCL_VERIFY's kernel-analysis memo survived
        # clear_exec_caches(), so a kernel edited mid-process kept its
        # stale clean verdict
        import jax
        import jax.numpy as jnp

        from repro.analysis import kernel_lint
        from repro.kernels.matmul.kernel import matmul_pallas

        kernel_lint.clear_verified_cache()
        sds = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        wds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        kernel_lint.verify_entry_point(
            "matmul", matmul_pallas, (sds, wds), {"block_m": 64}
        )
        assert kernel_lint._VERIFIED
        exec_engine.clear_exec_caches()
        assert not kernel_lint._VERIFIED


# --------------------------------------------------- ring_ef8 in the core
class TestRingEf8Schedule:
    def test_same_transfers_quarter_wire(self):
        exact = S.ring_all_reduce(8, _D)
        ef8 = S.ring_ef8_all_reduce(8, _D)
        assert ef8.collective == "all_reduce"
        assert ef8.algorithm == "ring_ef8"
        assert len(ef8.rounds) == len(exact.rounds)
        for re_, rx in zip(ef8.rounds, exact.rounds):
            assert re_.transfers == rx.transfers
            assert re_.size == pytest.approx(0.25 * rx.size)
        assert ef8.fingerprint() != exact.fingerprint()

    def test_registered_generator(self):
        built = S.get_schedule("all_reduce", "ring_ef8", 8, _D)
        assert built.algorithm == "ring_ef8"
        assert built.fingerprint() == S.ring_ef8_all_reduce(8, _D).fingerprint()

    def test_error_bound_values(self):
        assert compressed_ef_error_bound(2) == pytest.approx(1 / 127.0)
        assert compressed_ef_error_bound(8) == pytest.approx(7 / 127.0)
        # monotone in n: more quantizing hops, looser bound
        bounds = [compressed_ef_error_bound(n) for n in range(2, 32)]
        assert bounds == sorted(bounds)
        with pytest.raises(ValueError):
            compressed_ef_error_bound(1)


class TestRingEf8Arbitration:
    def test_candidates_gated_by_tolerance(self):
        base = candidate_algorithms("all_reduce", 8, "auto")
        assert "ring_ef8" not in base
        loose = candidate_algorithms("all_reduce", 8, "auto", 1.0)
        assert "ring_ef8" in loose
        assert set(loose) >= set(base)
        # tolerance below the n=8 bound (7/127 ~ 0.055) keeps the sum exact
        tight = candidate_algorithms("all_reduce", 8, "auto", 0.01)
        assert "ring_ef8" not in tight
        # boundary: exactly the bound is acceptable
        at = candidate_algorithms("all_reduce", 8, "auto", 7 / 127.0)
        assert "ring_ef8" in at

    def test_only_all_reduce_and_auto(self):
        assert "ring_ef8" not in candidate_algorithms(
            "reduce_scatter", 8, "auto", 1.0
        )
        assert candidate_algorithms("all_reduce", 8, "ring", 1.0) == ["ring"]
        assert candidate_algorithms("all_reduce", 8, "ring_ef8") == ["ring_ef8"]

    def test_session_plans_ef8_only_within_tolerance(self):
        from repro.api import PcclSession

        nbytes = 1e9
        s = PcclSession(cm.TPU_V5E_PHOTONIC, thread_fabric=False)
        exact = s.plan("all_reduce", nbytes, n=8, algorithm="auto")
        lossy = s.plan("all_reduce", nbytes, n=8, algorithm="auto",
                       rel_error_tol=1.0)
        tight = s.plan("all_reduce", nbytes, n=8, algorithm="auto",
                       rel_error_tol=1e-3)
        assert exact.algorithm != "ring_ef8"
        assert lossy.algorithm == "ring_ef8"
        assert lossy.cost < exact.cost  # the 4x wire discount must show up
        assert tight.algorithm == exact.algorithm
        assert tight.cost == exact.cost


# ------------------------------------------------------ matmul kernel ops
class TestMatmulKernel:
    @pytest.mark.parametrize(
        "dtype,tol", [("float32", 2e-5), ("bfloat16", 2e-2)]
    )
    def test_matches_reference(self, dtype, tol):
        import jax.numpy as jnp

        from repro.kernels.matmul import matmul, matmul_reference

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(256, 256)), dtype=dtype)
        w = jnp.asarray(rng.normal(size=(256, 128)), dtype=dtype)
        got = matmul(x, w, block_m=64, block_n=128, block_k=128,
                     use_pallas=True, interpret=True)
        want = matmul_reference(x, w)
        assert got.dtype == want.dtype
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            rtol=tol, atol=tol,
        )

    def test_chunked_calls_bit_identical_to_whole(self):
        # the fused path's correctness keystone: per-chunk kernel calls at
        # the same block sizes reproduce the whole-M call bit-for-bit
        import jax.numpy as jnp

        from repro.kernels.matmul import matmul

        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(256, 128)), dtype=jnp.float32)
        w = jnp.asarray(rng.normal(size=(128, 128)), dtype=jnp.float32)
        whole = matmul(x, w, block_m=32, use_pallas=True, interpret=True)
        parts = [
            matmul(x[i: i + 32], w, block_m=32, use_pallas=True,
                   interpret=True)
            for i in range(0, 256, 32)
        ]
        np.testing.assert_array_equal(
            np.asarray(whole), np.concatenate([np.asarray(p) for p in parts])
        )

    def test_tiles_exactly_and_fallback(self):
        import jax.numpy as jnp

        from repro.kernels.matmul import matmul, matmul_reference, tiles_exactly

        assert tiles_exactly(256, 128, 128, block_m=64)
        assert not tiles_exactly(250, 128, 128, block_m=64)
        # K=100 clips block_k to 100 (tiles); an explicit smaller block
        # that does not divide K does not
        assert tiles_exactly(256, 100, 128)
        assert not tiles_exactly(256, 100, 128, block_k=64)
        # non-tiling shapes silently dispatch to the reference (no padding)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(250, 100)), dtype=jnp.float32)
        w = jnp.asarray(rng.normal(size=(100, 64)), dtype=jnp.float32)
        got = matmul(x, w, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(matmul_reference(x, w))
        )

    def test_shape_validation(self):
        import jax.numpy as jnp

        from repro.kernels.matmul.kernel import matmul_pallas

        x = jnp.zeros((64, 128), jnp.float32)
        with pytest.raises(ValueError):
            matmul_pallas(x, jnp.zeros((64, 64), jnp.float32))  # K mismatch
        with pytest.raises(ValueError):
            matmul_pallas(x, jnp.zeros((128, 100), jnp.float32),
                          block_n=64)  # N=100 not tiled


# --------------------------------------------- taskgraph overlap modeling
class TestTaskgraphOverlap:
    def _sim(self, **kw):
        from benchmarks.taskgraph import CommScheme, Workload, simulate_training
        from repro.core import topology as T

        return simulate_training(
            Workload(), CommScheme("pccl", "pccl"), T.ring(8),
            cm.TPU_V5E_PHOTONIC, **kw,
        )

    def test_default_and_zero_fraction_unchanged(self):
        base = self._sim()
        zero = self._sim(overlap_fraction=0.0)
        assert zero.iteration_s == base.iteration_s
        assert zero.comm_s == base.comm_s

    def test_overlap_hides_comm_not_compute(self):
        base = self._sim()
        ov = self._sim(overlap_fraction=0.43)
        full = self._sim(overlap_fraction=1.0)
        assert ov.comm_s < base.comm_s
        assert full.comm_s <= ov.comm_s
        assert ov.compute_s == base.compute_s
        assert ov.iteration_s == pytest.approx(ov.comm_s + ov.compute_s)
        # the cold layer-1 AllReduce and one warm AllReduce never hide
        assert full.comm_s > 0

    def test_fraction_validated(self):
        with pytest.raises(ValueError):
            self._sim(overlap_fraction=1.5)

    def test_measured_overlap_fraction(self, tmp_path):
        import json

        from benchmarks.taskgraph import measured_overlap_fraction

        p = tmp_path / "BENCH_exec.json"
        p.write_text(json.dumps({"points": [
            {"collective": "fused_matmul_reduce_scatter",
             "seq_warm_s": 10.0, "fused_warm_s": 6.0},
            {"collective": "fused_matmul_reduce_scatter",
             "seq_warm_s": 10.0, "fused_warm_s": 4.0},
            {"collective": "reduce_scatter", "speedup": 100.0},
        ]}))
        assert measured_overlap_fraction(p) == pytest.approx(0.6)
        p.write_text(json.dumps({"points": [{"collective": "all_gather"}]}))
        assert measured_overlap_fraction(p) is None

    def test_committed_bench_has_fused_rows(self):
        # the committed baseline must keep feeding the overlap model
        from benchmarks.taskgraph import measured_overlap_fraction

        frac = measured_overlap_fraction(ROOT / "BENCH_exec.json")
        assert frac is not None and 0.0 < frac < 1.0


# --------------------------------------------------- bench gate schema
def test_bench_gate_identifies_fused_rows():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "bench_gate", ROOT / "scripts" / "bench_gate.py"
    )
    gate = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gate)
    assert "shape" in gate.ID_KEYS and "mode" in gate.ID_KEYS
    a = {"n": 8, "collective": "fused_matmul_reduce_scatter",
         "shape": "256x128x128", "mode": "fused", "speedup": 1.4}
    b = dict(a, shape="512x128x128")
    assert gate.point_id(a) != gate.point_id(b)
    assert gate.point_id(a) == gate.point_id(dict(a, speedup=9.9))


# ------------------------------------------------------- device subprocess
@pytest.mark.slow
@pytest.mark.multidevice
def test_fusion_device_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "fusion_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-FUSION-OK" in proc.stdout
