"""Hierarchical + incremental planning: pods=1 bit-identity, stitched-cost
quality vs the flat exact DP, warm replanning vs cold replanning, invariant
replay of both levels, and byte-charged cache eviction."""

import numpy as np
import pytest

from conftest import hypothesis_or_stubs

from repro.analysis.invariants import (
    check_hierarchical_plan,
    check_plan,
)
from repro.api.session import PcclSession, StructureCache
from repro.core import cost_model as cm
from repro.core.cost_model import STRUCTURE_TABLE, StructureTable
from repro.core.pccl import (
    CollectiveRequest,
    default_standard_set,
    plan_collective,
    plan_collective_hierarchical,
    replan_collective,
)
from repro.core.planner import (
    build_structure,
    clear_planner_caches,
    plan,
    plan_hierarchical,
    replan,
    trans_cache_stats,
)
from repro.core.schedules import get_schedule, pod_subschedules
from repro.core.topology import (
    degrade_topology,
    derive_pods,
    induced_topology,
    quotient_topology,
    ring,
)
from repro.runtime import fault as fault_mod

given, settings, st = hypothesis_or_stubs()

HW = cm.H100_DGX
MB = 1 << 20

COLLECTIVES = [
    ("all_reduce", "ring"),
    ("reduce_scatter", "ring"),
    ("all_gather", "ring"),
    ("all_to_all", "direct"),
]

MODES = ["serial", "partial", "overlap"]


def _hw_for(mode):
    if mode == "serial":
        return HW
    r_link = HW.reconfig_delay / 64
    return HW.with_link_reconfig(r_link, overlap=(mode == "overlap"))


# ------------------------------------------------------------- decomposition


def test_derive_pods_partition():
    pods = derive_pods(1024)
    assert len(pods) == 32 and all(len(p) == 32 for p in pods)
    assert sorted(r for p in pods for r in p) == list(range(1024))
    with pytest.raises(ValueError):
        derive_pods(16, pod_size=5)


def test_pod_subschedules_conserves_transfers():
    n = 16
    pods = derive_pods(n, pod_size=4)
    for coll, algo in COLLECTIVES:
        sched = get_schedule(coll, algo, n, float(MB))
        intra, rep, boundary = pod_subschedules(sched, pods)
        for i, rnd in enumerate(sched.rounds):
            want_cross = {}
            want_local = {p: [] for p in range(len(pods))}
            for t in rnd.transfers:
                if t.src == t.dst:
                    continue
                ps, pd = t.src // 4, t.dst // 4
                if ps == pd:
                    want_local[ps].append((t.src % 4, t.dst % 4))
                else:
                    want_cross[(ps, pd)] = want_cross.get((ps, pd), 0) + 1
            assert tuple(sorted(want_cross.items())) == boundary[i]
            for p in range(len(pods)):
                got = sorted(
                    (t.src, t.dst)
                    for t in intra[rep[p]].rounds[i].transfers
                )
                assert got == sorted(want_local[p]), (coll, i, p)


# ---------------------------------------------------------- pods=1 identity


@pytest.mark.parametrize("coll,algo", COLLECTIVES)
def test_single_pod_is_flat_dp_bit_identical(coll, algo):
    n = 16
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule(coll, algo, n, float(MB))
    flat = plan(g0, std, sched, HW)
    hp = plan_hierarchical(g0, std, sched, HW, pod_size=n)
    assert hp.inter_plan is None
    assert hp.pod_plans[0].plan.steps == flat.steps
    assert hp.pod_plans[0].plan.total_cost == flat.total_cost
    assert hp.total_cost == flat.total_cost


# --------------------------------------------------------- stitched quality


@pytest.mark.parametrize("n", [16, 64, 128])
@pytest.mark.parametrize("mode", MODES)
def test_hierarchical_within_ten_percent_of_flat(n, mode):
    hw = _hw_for(mode)
    g0, std = ring(n), default_standard_set(n)
    for coll, algo in COLLECTIVES:
        sched = get_schedule(coll, algo, n, float(MB))
        flat = plan(g0, std, sched, hw)
        hp = plan_hierarchical(g0, std, sched, hw)
        ratio = hp.total_cost / flat.total_cost
        assert ratio <= 1.1, (n, mode, coll, algo, ratio)


def test_hierarchical_invariant_replay():
    n = 64
    g0, std = ring(n), default_standard_set(n)
    for coll, algo in COLLECTIVES:
        sched = get_schedule(coll, algo, n, float(MB))
        hp = plan_hierarchical(g0, std, sched, HW)
        violations = check_hierarchical_plan(hp, g0, std)
        assert not violations, [str(v) for v in violations]


def test_hierarchical_invariant_attributes_tampering():
    from dataclasses import replace

    n = 16
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule("all_to_all", "direct", n, float(MB))
    hp = plan_hierarchical(g0, std, sched, HW, pod_size=4)

    bad = replace(hp, total_cost=hp.total_cost * 2)
    assert any(
        v.kind == "total-cost" for v in check_hierarchical_plan(bad, g0, std)
    )
    bad = replace(hp, round_costs=(hp.round_costs[0] * 3,) + hp.round_costs[1:])
    assert any(
        v.kind == "round-cost-stitching"
        for v in check_hierarchical_plan(bad, g0, std)
    )
    bad = replace(hp, boundary=(((0, 1), 99),) * len(hp.boundary))
    kinds = {v.kind for v in check_hierarchical_plan(bad, g0, std)}
    assert "boundary-conservation" in kinds or "boundary-length" in kinds


def test_hierarchical_arbitration_facade():
    n = 64
    g0 = ring(n)
    req = CollectiveRequest("all_reduce", n, float(MB))
    pp = plan_collective_hierarchical(req, g0, HW)
    assert pp.plan.total_cost == pp.cost
    assert pp.plan.final_topology is None


# ------------------------------------------------------------------- replan


def _degraded_inputs(n, failed_edges):
    fe = [e for (u, v) in failed_edges for e in ((u, v), (v, u))]
    g0 = degrade_topology(ring(n), fe)
    std = [degrade_topology(t, fe) for t in default_standard_set(n)]
    return g0, std


@pytest.mark.parametrize("coll,algo", COLLECTIVES)
def test_replan_equals_cold_on_degraded_fabric(coll, algo):
    n = 16
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule(coll, algo, n, float(MB))
    structure = build_structure(g0, std, sched, HW)
    failed = ((3, 4), (4, 3))
    warm, new_structure = replan(
        g0, std, sched, HW, structure, changed_edges=failed
    )
    d_g0, d_std = _degraded_inputs(n, [(3, 4)])
    cold = plan(d_g0, d_std, sched, HW)
    assert warm.steps == cold.steps
    assert warm.total_cost == cold.total_cost
    assert not check_plan(warm, d_g0, d_std)
    # the refreshed structure warm-replans a second failure too
    warm2, _ = replan(
        d_g0, d_std, sched, HW, new_structure, changed_edges=((8, 9), (9, 8))
    )
    d2_g0 = degrade_topology(d_g0, ((8, 9), (9, 8)))
    d2_std = [degrade_topology(t, ((8, 9), (9, 8))) for t in d_std]
    cold2 = plan(d2_g0, d2_std, sched, HW)
    assert warm2.steps == cold2.steps


@given(
    edge=st.integers(min_value=0, max_value=15),
    coll_idx=st.integers(min_value=0, max_value=3),
)
@settings(max_examples=20, deadline=None)
def test_replan_property_single_link_failures(edge, coll_idx):
    n = 16
    coll, algo = COLLECTIVES[coll_idx]
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule(coll, algo, n, float(MB))
    structure = build_structure(g0, std, sched, HW)
    failed = ((edge, (edge + 1) % n), ((edge + 1) % n, edge))
    warm, _ = replan(g0, std, sched, HW, structure, changed_edges=failed)
    d_g0, d_std = _degraded_inputs(n, [failed[0]])
    cold = plan(d_g0, d_std, sched, HW)
    assert warm.steps == cold.steps
    assert warm.total_cost == cold.total_cost


def test_replan_without_structure_falls_back_cold():
    n = 16
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule("all_reduce", "ring", n, float(MB))
    warm, _ = replan(g0, std, sched, HW, None, changed_edges=((0, 1), (1, 0)))
    d_g0, d_std = _degraded_inputs(n, [(0, 1)])
    cold = plan(d_g0, d_std, sched, HW)
    assert warm.steps == cold.steps


def test_replan_collective_facade_matches_cold_arbitration():
    n = 16
    g0, std = ring(n), default_standard_set(n)
    req = CollectiveRequest("all_to_all", n, float(MB), algorithm="direct")
    warm = replan_collective(
        req, g0, HW, standard=std, changed_edges=((5, 6), (6, 5))
    )
    d_g0, d_std = _degraded_inputs(n, [(5, 6)])
    cold = plan_collective(req, d_g0, HW, standard=d_std)
    assert warm.cost == cold.cost
    assert warm.plan.steps == cold.plan.steps


# --------------------------------------------------------------- session API


def test_session_replan_is_warm_and_permanent():
    n = 128
    clear_planner_caches()
    STRUCTURE_TABLE.clear()
    s = PcclSession(HW, g0=ring(n), thread_fabric=False)
    s.plan("all_to_all", float(MB), algorithm="direct")
    cold_routes = STRUCTURE_TABLE.stats.routing_calls
    assert cold_routes > 0

    before = STRUCTURE_TABLE.stats.routing_calls
    rp = s.replan(
        "all_to_all", float(MB), algorithm="direct", failed_edges=[(0, 1)]
    )
    warm_routes = STRUCTURE_TABLE.stats.routing_calls - before
    # warm path re-routes only states the dead link touched: a small
    # fraction of the cold structure phase (the >=10x wall-clock claim is
    # measured in benchmarks/planner_bench.py; this is its deterministic
    # routing-work proxy)
    assert warm_routes <= 0.2 * cold_routes, (warm_routes, cold_routes)

    # permanence: fabric, initial fabric and standards all lost the link
    for topo in (s.fabric(n), s.initial_fabric(n), *s.standard_set(n)):
        assert (0, 1) not in topo.edges and (1, 0) not in topo.edges

    # the warm plan equals a cold plan of the degraded scenario
    d_g0, d_std = _degraded_inputs(n, [(0, 1)])
    s2 = PcclSession(HW, g0=d_g0, standard_set=d_std, thread_fabric=False)
    cold = s2.plan("all_to_all", float(MB), algorithm="direct")
    assert rp.plan.steps == cold.plan.steps
    assert rp.cost == cold.cost
    assert not check_plan(rp.plan, d_g0, d_std)


def test_session_replan_via_fault_event():
    n = 16
    s = PcclSession(HW, g0=ring(n), thread_fabric=False)
    s.plan("all_reduce", float(MB))
    ev = fault_mod.LinkFailure(edges=((2, 3),))
    p = fault_mod.replan_after_failure(s, ev, "all_reduce", float(MB), n=n)
    assert (2, 3) not in s.fabric(n).edges
    assert p.cost > 0
    with pytest.raises(ValueError):
        fault_mod.LinkFailure()


def test_session_plan_hierarchical_cached():
    s = PcclSession(HW, g0=ring(64), thread_fabric=False)
    hp = s.plan_hierarchical("all_reduce", float(MB))
    assert s.plan_hierarchical("all_reduce", float(MB)) is hp
    assert hp.plan.final_topology is None
    # no fabric threading from hierarchical plans
    assert s.fabric(64).edges == ring(64).edges


def test_communicator_replan_forwards():
    s = PcclSession(HW, g0=ring(16), thread_fabric=False)
    comm = s.communicator("x", 16, algorithm="paper_default")
    p = comm.replan("all_reduce", float(MB), failed_edges=[(1, 2)])
    assert (1, 2) not in s.fabric(16).edges
    assert p.cost > 0


# ----------------------------------------------------------- byte accounting


def test_structure_table_byte_eviction():
    t = StructureTable(max_entries=1000, max_bytes=8_000)
    topo = ring(8)
    for i in range(200):
        key = frozenset({((i, (i + 1) % 1000), 1)})
        t.store(topo, key, (1, 1, True))
    st_ = t.stats
    assert st_.bytes <= 8_000
    assert st_.evictions > 0
    assert st_.size >= 1


def test_trans_cache_reports_and_bounds_bytes():
    clear_planner_caches()
    n = 16
    g0, std = ring(n), default_standard_set(n)
    sched = get_schedule("all_reduce", "ring", n, float(MB))
    plan(g0, std, sched, HW)
    entries, nbytes = trans_cache_stats()
    assert entries >= 1 and nbytes > 0


def test_session_structure_cache_byte_eviction():
    c = StructureCache(max_entries=100, max_bytes=1)
    n = 16
    g0, std = ring(n), default_standard_set(n)
    s1 = build_structure(g0, std, get_schedule("all_reduce", "ring", n, 1.0), HW)
    s2 = build_structure(
        g0, std, get_schedule("all_gather", "ring", n, 1.0), HW
    )
    c.store(("a",), {"ring": s1})
    assert c.stats.size == 1  # a single oversized bundle still caches
    c.store(("b",), {"ring": s2})
    assert c.stats.size == 1 and c.stats.evictions >= 1
    assert c.stats.bytes <= max(c._charge({"ring": s1}), c._charge({"ring": s2}))
    # re-storing a mutated bundle replaces its charge instead of accumulating
    c.clear()
    bundle = {"ring": s1}
    c.store(("a",), bundle)
    b1 = c.stats.bytes
    bundle["ring2"] = s2
    c.store(("a",), bundle)
    assert c.stats.bytes > b1
    c.store(("a",), bundle)
    assert c.stats.bytes == c._charge(bundle)


def test_session_structure_stats_totals():
    s = PcclSession(HW, g0=ring(16), thread_fabric=False)
    s.plan("all_reduce", float(MB))
    st_ = s.structure_stats
    assert st_.bytes > 0
    assert st_.table_bytes > 0 and st_.table_entries > 0
    assert st_.trans_bytes > 0 and st_.trans_entries > 0
    assert st_.misses >= 1  # CacheStats interface intact


def test_build_structure_prunes_dead_states():
    n = 8
    fe = [(0, 1), (1, 0), (0, 7), (7, 0)]  # isolate rank 0 in the ring
    g0 = degrade_topology(ring(n), fe)
    std = [degrade_topology(t, fe) for t in default_standard_set(n)]
    sched = get_schedule("all_reduce", "ring", n, float(MB))
    structure = build_structure(g0, std, sched, HW)
    # disconnected standards are pruned but recorded for reuse validation
    names = {s.topo.edges for s in structure.states}
    for pruned in structure.pruned_standard:
        assert pruned not in names
    # healthy fabric: nothing pruned, bit-identical planning
    healthy = build_structure(ring(n), default_standard_set(n), sched, HW)
    assert not healthy.pruned_standard
