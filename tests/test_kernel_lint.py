"""Kernel static analyzer tests.

Three layers, mirroring ``tests/test_verify_mutations.py``'s structure for
the schedule verifier:

1. **clean passes** — every shipped Pallas kernel case analyzes clean;
2. **rule-by-rule** — each violation kind is triggered by a minimal
   synthetic ``pallas_call`` (defined at module level so the AST rules can
   read their source), including a regression for the flash kernel's
   pre-fix dead ``q_offset_blocks`` parameter;
3. **seeded mutation corpus** — corrupted index maps, off-by-one grids,
   swapped block dims and dropped scratch resets applied to the *real*
   captured kernels, with an explicit survivor triage.

Survivor triage
---------------
The analyzer proves structural safety: bounds, exact output coverage,
race freedom, carry discipline.  It does **not** model kernel arithmetic,
so a mutated *input* index map whose footprints stay in bounds reads the
wrong (but valid) data — invisible to spec-level analysis, numerically
visible to the interpret-mode parity tests in ``tests/test_kernels.py``.
Those in-bounds input-read mutants are the only allowed survivor class;
anything else surviving is an analyzer hole and fails outright.
"""

import copy
import itertools
import random

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernel_lint import (
    KINDS,
    KernelLintError,
    analyze_call_site,
    analyze_callable,
    clear_verified_cache,
    shipped_kernel_cases,
    summarize_kernel,
    verify_entry_point,
)
from repro.analysis.pallas_model import (
    BlockModel,
    CaptureError,
    capture_call_sites,
)

f32 = jnp.float32
bf16 = jnp.bfloat16
SDS = jax.ShapeDtypeStruct


def _kinds(report):
    return {v.kind for v in report.violations}


# --------------------------------------------------------- 1. clean passes

CASES = shipped_kernel_cases()


@pytest.mark.parametrize("case", CASES, ids=[c[0] for c in CASES])
def test_shipped_kernels_analyze_clean(case):
    label, fn, args, kwargs = case
    reports = analyze_callable(fn, *args, **kwargs)
    assert reports, label
    for r in reports:
        assert r.ok, f"{label}: {r}"
        assert r.programs_checked > 0


def test_capture_requires_a_pallas_call():
    """A wrapper that never reaches pallas_call must not pass vacuously."""
    with pytest.raises(CaptureError):
        capture_call_sites(lambda x: x + 1, SDS((8, 128), f32))


# --------------------------------------------- 2. rule-by-rule synthetics
#
# Kernels live at module level so inspect.getsource works (the AST rules
# skip exec-defined bodies by design).  Capture monkeypatches pallas_call,
# so none of these ever execute — only grid/specs/source matter.


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _one_in(grid, in_map, out_map, shape=(128, 128), block=(32, 128),
            dtype=f32, kernel=_copy_kernel, **kw):
    def wrap(x):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(block, in_map)],
            out_specs=pl.BlockSpec(block, out_map),
            out_shape=SDS(shape, dtype),
            **kw,
        )(x)

    return wrap


def test_coverage_gap_with_attribution():
    wrap = _one_in((2,), lambda i: (i, 0), lambda i: (i, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert _kinds(r) == {"coverage-gap"}
    [v] = r.violations
    assert v.box == (2, 0)  # first never-written block coordinate
    assert "2 of 4 blocks" in v.detail


def test_coverage_gap_ragged_blocks():
    wrap = _one_in((4,), lambda i: (i, 0), lambda i: (i, 0), shape=(100, 128))
    [r] = analyze_callable(wrap, SDS((100, 128), f32))
    assert "coverage-gap" in _kinds(r)  # 32 does not divide 100


def test_write_race_two_programs_same_block():
    wrap = _one_in((4,), lambda i: (i, 0), lambda i: (i % 2, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "write-race" in _kinds(r)
    race = [v for v in r.violations if v.kind == "write-race"]
    assert race[0].program is not None and race[0].box is not None


def test_write_race_parallel_axis_revisit():
    """An output whose index map ignores a *parallel* grid axis is a race;
    ignoring a sequential axis (ssd's fin) is a legal carry."""
    wrap = _one_in(
        (4,), lambda i: (i, 0), lambda i: (0, 0), shape=(32, 128),
        compiler_params=dict(mosaic=dict(dimension_semantics=("parallel",))),
    )
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "write-race" in _kinds(r)

    wrap = _one_in(
        (4,), lambda i: (i, 0), lambda i: (0, 0), shape=(32, 128),
        compiler_params=dict(mosaic=dict(dimension_semantics=("arbitrary",))),
    )
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "write-race" not in _kinds(r)


def test_oob_write_and_read():
    wrap = _one_in((4,), lambda i: (i, 0), lambda i: (i + 1, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "oob-write" in _kinds(r)

    wrap = _one_in((4,), lambda i: (i + 1, 0), lambda i: (i, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "oob-read" in _kinds(r)


def test_grid_empty_and_unenumerable():
    wrap = _one_in((0,), lambda i: (i, 0), lambda i: (i, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert _kinds(r) == {"grid-empty"}

    wrap = _one_in((1024, 1024), lambda i, j: (i, 0), lambda i, j: (i, 0))
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert _kinds(r) == {"grid-unenumerable"}  # explicit, never silent


def _alias_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def test_alias_footprint_mismatch():
    def wrap(x):
        return pl.pallas_call(
            _alias_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((32, 128), lambda i: (3 - i, 0))],
            out_specs=pl.BlockSpec((32, 128), lambda i: (i, 0)),
            out_shape=SDS((128, 128), f32),
            input_output_aliases={0: 0},
        )(x)

    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    assert "alias-mismatch" in _kinds(r)

    def wrap_ok(x):
        return pl.pallas_call(
            _alias_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((32, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((32, 128), lambda i: (i, 0)),
            out_shape=SDS((128, 128), f32),
            input_output_aliases={0: 0},
        )(x)

    [r] = analyze_callable(wrap_ok, SDS((128, 128), f32))
    assert r.ok, str(r)


def _carry_no_reset(x_ref, o_ref, acc_ref):
    acc_ref[...] = acc_ref[...] + x_ref[...]
    o_ref[...] = acc_ref[...]


def _carry_outer_reset(x_ref, o_ref, acc_ref):
    hi = pl.program_id(0)

    @pl.when(hi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = acc_ref[...] + x_ref[...]
    o_ref[...] = acc_ref[...]


def _carry_inner_reset(x_ref, o_ref, acc_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = acc_ref[...] + x_ref[...]
    o_ref[...] = acc_ref[...]


def _carry_wrap(kernel, **kw):
    def wrap(x):
        return pl.pallas_call(
            kernel,
            grid=(2, 4),
            in_specs=[pl.BlockSpec((None, 32, 128), lambda h, c: (h, c, 0))],
            out_specs=pl.BlockSpec((None, 32, 128), lambda h, c: (h, c, 0)),
            out_shape=SDS((2, 128, 128), f32),
            scratch_shapes=[pltpu.VMEM((32, 128), f32)],
            **kw,
        )(x)

    return wrap


def test_scratch_no_reset():
    [r] = analyze_callable(_carry_wrap(_carry_no_reset), SDS((2, 128, 128), f32))
    assert _kinds(r) == {"scratch-no-reset"}


def test_scratch_carry_axis_must_be_innermost():
    [r] = analyze_callable(_carry_wrap(_carry_outer_reset), SDS((2, 128, 128), f32))
    assert _kinds(r) == {"scratch-carry-axis"}
    [r] = analyze_callable(_carry_wrap(_carry_inner_reset), SDS((2, 128, 128), f32))
    assert r.ok, str(r)


def test_scratch_carry_parallel_axis():
    wrap = _carry_wrap(
        _carry_inner_reset,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "parallel"))
        ),
    )
    [r] = analyze_callable(wrap, SDS((2, 128, 128), f32))
    assert "scratch-carry-parallel" in _kinds(r)


def _uncast_store(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float32) * 2.0


def _raw_bf16_read(x_ref, o_ref):
    o_ref[...] = (x_ref[...] * 2.0).astype(o_ref.dtype)


def _clean_bf16(x_ref, o_ref):
    o_ref[...] = (x_ref[...].astype(jnp.float32) * 2.0).astype(o_ref.dtype)


def test_precision_rules_fire_only_for_sub_fp32():
    mk = lambda kernel, dtype: _one_in(
        (4,), lambda i: (i, 0), lambda i: (i, 0), dtype=dtype, kernel=kernel
    )
    [r] = analyze_callable(mk(_uncast_store, bf16), SDS((128, 128), bf16))
    assert _kinds(r) == {"missing-store-cast"}
    [r] = analyze_callable(mk(_raw_bf16_read, bf16), SDS((128, 128), bf16))
    assert _kinds(r) == {"low-precision-read"}
    [r] = analyze_callable(mk(_clean_bf16, bf16), SDS((128, 128), bf16))
    assert r.ok, str(r)
    # the same bodies on fp32 operands are fine: no upcast/cast needed
    [r] = analyze_callable(mk(_uncast_store, f32), SDS((128, 128), f32))
    assert r.ok, str(r)
    [r] = analyze_callable(mk(_raw_bf16_read, f32), SDS((128, 128), f32))
    assert r.ok, str(r)


def _prefix_flash(q_ref, o_ref, *, sm_scale, q_offset_blocks):
    # the flash kernel's pre-fix shape: the offset is multiplied by a
    # literal 0 ("folded in caller"), so the parameter does nothing
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_pos = qi * 32 + q_offset_blocks * 32 * 0
    o_ref[...] = (q + q_pos).astype(o_ref.dtype)


def _unused_param(x_ref, o_ref, *, scale, unused):
    o_ref[...] = x_ref[...] * scale


def test_dead_param_regression_prefix_flash():
    """The analyzer must flag the flash kernel's pre-fix dead
    ``q_offset_blocks`` (multiply-by-zero) — the rule that motivated
    deleting it."""
    import functools

    wrap = _one_in(
        (4,), lambda i: (i, 0), lambda i: (i, 0),
        kernel=functools.partial(_prefix_flash, sm_scale=1.0, q_offset_blocks=0),
    )
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    dead = [v for v in r.violations if v.kind == "dead-param"]
    assert len(dead) == 1 and dead[0].operand == "q_offset_blocks"
    assert "literal 0" in dead[0].detail


def test_dead_param_unused():
    import functools

    wrap = _one_in(
        (4,), lambda i: (i, 0), lambda i: (i, 0),
        kernel=functools.partial(_unused_param, scale=2.0, unused=7),
    )
    [r] = analyze_callable(wrap, SDS((128, 128), f32))
    dead = [v for v in r.violations if v.kind == "dead-param"]
    assert len(dead) == 1 and dead[0].operand == "unused"


def test_current_flash_kernel_has_no_dead_params():
    from repro.kernels.flash.kernel import flash_attention_pallas

    sites = capture_call_sites(
        flash_attention_pallas,
        SDS((1, 256, 2, 32), f32), SDS((1, 256, 2, 32), f32),
        SDS((1, 256, 2, 32), f32), causal=True,
    )
    summ = summarize_kernel(sites[0].kernel, 3, 1, 0)
    assert summ.parsed
    r = analyze_call_site(sites[0])
    assert not any(v.kind == "dead-param" for v in r.violations), str(r)


def test_vmem_budget():
    wrap = _one_in((4,), lambda i: (i, 0), lambda i: (i, 0))
    [site] = capture_call_sites(wrap, SDS((128, 128), f32))
    r = analyze_call_site(site, vmem_budget=1024)
    assert "vmem-budget" in _kinds(r)
    assert analyze_call_site(site).ok  # default 16 MiB budget is fine


def test_violation_kinds_are_stable():
    """Every kind the synthetics produce is declared in KINDS (docs/tests
    key on these strings)."""
    assert len(KINDS) == len(set(KINDS))
    for k in ("coverage-gap", "write-race", "oob-read", "oob-write",
              "scratch-no-reset", "dead-param", "missing-store-cast"):
        assert k in KINDS


# ------------------------------------------------ PCCL_VERIFY entry points


def test_verify_entry_point_gate():
    clear_verified_cache()
    ok = _one_in((4,), lambda i: (i, 0), lambda i: (i, 0))
    bad = _one_in((2,), lambda i: (i, 0), lambda i: (i, 0))  # coverage gap

    verify_entry_point("lint-ok", ok, (SDS((128, 128), f32),))
    verify_entry_point("lint-ok", ok, (SDS((128, 128), f32),))  # memo hit
    with pytest.raises(KernelLintError) as ei:
        verify_entry_point("lint-bad", bad, (SDS((128, 128), f32),))
    assert "coverage-gap" in str(ei.value)
    clear_verified_cache()


def test_ops_dispatch_verifies_under_env(monkeypatch):
    """PCCL_VERIFY=1 runs the analyzer at the ops entry point, then the
    kernel itself — clean kernels pass through unchanged."""
    import numpy as np

    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_reference

    clear_verified_cache()
    monkeypatch.setenv("PCCL_VERIFY", "1")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)), f32)
    w = jnp.asarray(rng.normal(size=(64,)) + 1.0, f32)
    got = rmsnorm(x, w, use_pallas=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(rmsnorm_reference(x, w)), rtol=2e-6, atol=2e-6
    )
    clear_verified_cache()


# ------------------------------------------------- 3. mutation corpus


def _flash_base():
    from repro.kernels.flash.kernel import flash_attention_pallas

    [site] = capture_call_sites(
        flash_attention_pallas,
        SDS((1, 256, 2, 32), f32), SDS((1, 256, 1, 32), f32),
        SDS((1, 256, 1, 32), f32), causal=True, block_q=64, block_k=64,
    )
    return site


def _rmsnorm_base():
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas

    [site] = capture_call_sites(
        rmsnorm_pallas, SDS((300, 100), f32), SDS((100,), f32)
    )
    return site


def _ssd_base():
    from repro.kernels.ssd.kernel import ssd_pallas

    [site] = capture_call_sites(
        ssd_pallas, SDS((1, 80, 2, 16), f32), SDS((1, 80, 2), f32),
        SDS((1, 80, 2, 8), f32), SDS((1, 80, 2, 8), f32), chunk=32,
    )
    return site


def _summary_of(site):
    return summarize_kernel(
        site.kernel, len(site.in_blocks), len(site.out_blocks),
        len(site.scratch_shapes),
    )


def _mapped(block, transform):
    base = block.index_map

    def index_map(*ids):
        out = base(*ids)
        if not isinstance(out, tuple):
            out = (out,)
        return transform(out)

    return BlockModel(block.block_shape, index_map)


def _replace_spec(site, idx, block):
    if idx < len(site.in_blocks):
        return site.with_in_block(idx, block), "in"
    return site.with_out_block(idx - len(site.in_blocks), block), "out"


def _pick_spec(rng, site):
    return rng.randrange(len(site.in_blocks) + len(site.out_blocks))


def _spec_at(site, idx):
    blocks = site.in_blocks + site.out_blocks
    return blocks[idx]


# operators: (rng, site, summary) -> (site', summary', role)

def mut_index_bump(rng, site, summ):
    idx = _pick_spec(rng, site)
    blk = _spec_at(site, idx)
    d = rng.randrange(len(blk.block_shape))
    site, role = _replace_spec(site, idx, _mapped(
        blk, lambda c, _d=d: tuple(x + (1 if j == _d else 0)
                                   for j, x in enumerate(c))))
    return site, summ, role


def mut_index_swap(rng, site, summ):
    idx = _pick_spec(rng, site)
    blk = _spec_at(site, idx)
    nd = len(blk.block_shape)
    if nd < 2:
        return site, summ, "noop"
    i, j = rng.sample(range(nd), 2)

    def swap(c, _i=i, _j=j):
        c = list(c)
        c[_i], c[_j] = c[_j], c[_i]
        return tuple(c)

    site, role = _replace_spec(site, idx, _mapped(blk, swap))
    return site, summ, role


def mut_index_const_zero(rng, site, summ):
    idx = _pick_spec(rng, site)
    blk = _spec_at(site, idx)
    site, role = _replace_spec(site, idx, _mapped(
        blk, lambda c: (0,) * len(c)))
    return site, summ, role


def mut_grid_plus1(rng, site, summ):
    import dataclasses

    a = rng.randrange(len(site.grid))
    grid = tuple(g + (1 if i == a else 0) for i, g in enumerate(site.grid))
    return dataclasses.replace(site, grid=grid), summ, "grid"


def mut_grid_minus1(rng, site, summ):
    import dataclasses

    a = rng.randrange(len(site.grid))
    grid = tuple(g - (1 if i == a else 0) for i, g in enumerate(site.grid))
    return dataclasses.replace(site, grid=grid), summ, "grid"


def mut_block_swap_dims(rng, site, summ):
    idx = _pick_spec(rng, site)
    blk = _spec_at(site, idx)
    nd = len(blk.block_shape)
    if nd < 2:
        return site, summ, "noop"
    i, j = rng.sample(range(nd), 2)
    shape = list(blk.block_shape)
    shape[i], shape[j] = shape[j], shape[i]
    site, role = _replace_spec(
        site, idx, BlockModel(tuple(shape), blk.index_map))
    return site, summ, role


def mut_drop_reset(rng, site, summ):
    summ = copy.deepcopy(summ)
    summ.resets.clear()
    return site, summ, "summary"


def mut_reset_axis_shift(rng, site, summ):
    summ = copy.deepcopy(summ)
    summ.resets = {k: {a - 1 for a in v} for k, v in summ.resets.items()}
    return site, summ, "summary"


OPERATORS = [mut_index_bump, mut_index_swap, mut_index_const_zero,
             mut_grid_plus1, mut_grid_minus1, mut_block_swap_dims,
             mut_drop_reset, mut_reset_axis_shift]


def _fingerprint(site, summ):
    """Footprint-level identity: mutants indistinguishable from the base
    here are *equivalent* for a spec-level analyzer and excluded."""
    fps = []
    for p in itertools.product(*(range(g) for g in site.grid)):
        row = []
        for blk in site.in_blocks + site.out_blocks:
            try:
                b = blk.footprint(p)
                row.append((b.offset, b.size))
            except Exception:
                row.append("err")
        fps.append(tuple(row))
    resets = frozenset(
        (k, frozenset(v)) for k, v in (summ.resets if summ else {}).items()
    )
    return (site.grid, tuple(fps), resets)


def _gen_mutants(seed=20260807, per_pair=4):
    rng = random.Random(seed)
    bases = [("flash", _flash_base()), ("rmsnorm", _rmsnorm_base()),
             ("ssd", _ssd_base())]
    mutants = []
    for name, site in bases:
        summ = _summary_of(site)
        assert summ.parsed, name
        base_fp = _fingerprint(site, summ)
        for op in OPERATORS:
            for _ in range(per_pair):
                m_site, m_summ, role = op(rng, site, summ)
                if role == "noop":
                    continue
                if _fingerprint(m_site, m_summ) == base_fp:
                    continue  # equivalent at the footprint level
                mutants.append((name, op.__name__, role, m_site, m_summ))
    return mutants


def _exec_site(site, arrays):
    """Re-materialize a (possibly mutated) CallSite as a real interpret-mode
    pallas_call and run it — the numeric oracle for static survivors.  Only
    sound for sites the bounds check accepted (survivors, by definition)."""
    import numpy as np

    in_specs = [pl.BlockSpec(b.block_shape, b.index_map) for b in site.in_blocks]
    out_specs = [pl.BlockSpec(b.block_shape, b.index_map) for b in site.out_blocks]
    multi = len(site.out_blocks) > 1
    out_shape = [SDS(s, np.dtype(d))
                 for s, d in zip(site.out_shapes, site.out_dtypes)]
    scratch = [pltpu.VMEM(s, np.dtype(d))
               for s, d in zip(site.scratch_shapes, site.scratch_dtypes)]
    out = pl.pallas_call(
        site.kernel,
        grid=site.grid,
        in_specs=in_specs,
        out_specs=out_specs if multi else out_specs[0],
        out_shape=out_shape if multi else out_shape[0],
        scratch_shapes=scratch,
        interpret=True,
    )(*arrays)
    leaves = out if multi else [out]
    return [np.asarray(leaf, np.float32) for leaf in leaves]


def _operands_for(site, seed=0):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=s), np.dtype(d))
            for s, d in zip(site.in_shapes, site.in_dtypes)]


def test_mutation_kill_rate():
    """>= 90% of non-equivalent mutants killed statically; every static
    survivor triaged AND killed by the numeric oracle.

    Allowed static-survivor class (module docstring): an *input* index-map
    mutation whose footprints stay within bounds — the reads are valid but
    wrong, which spec-level analysis cannot see.  For each such survivor we
    re-execute the mutated call site in interpret mode and require its
    output to differ from the base — the demonstration that the
    interpret-mode parity tests in test_kernels.py are the complementary
    oracle.  Grid, output, block-shape and reset mutations must all be
    killed statically.
    """
    import numpy as np

    mutants = _gen_mutants()
    assert len(mutants) >= 50  # the corpus is not degenerate

    bases = {"flash": _flash_base(), "rmsnorm": _rmsnorm_base(),
             "ssd": _ssd_base()}
    base_out = {}

    killed_static = 0
    killed_numeric = []
    unexplained = []
    for name, op_name, role, m_site, m_summ in mutants:
        r = analyze_call_site(m_site, summary=m_summ)
        if r.violations:
            killed_static += 1
            continue
        if not (role == "in" and op_name in (
                "mut_index_const_zero", "mut_index_swap", "mut_index_bump")):
            unexplained.append((name, op_name, role))
            continue
        # triaged class: must be numerically visible in interpret mode
        if name not in base_out:
            base_out[name] = _exec_site(bases[name], _operands_for(bases[name]))
        got = _exec_site(m_site, _operands_for(bases[name]))
        if any(not np.allclose(g, b, rtol=1e-4, atol=1e-4)
               for g, b in zip(got, base_out[name])):
            killed_numeric.append((name, op_name))
        else:
            unexplained.append((name, op_name, "numeric-equal"))

    assert not unexplained, f"untriaged survivors: {unexplained}"

    total = len(mutants)
    rate = (killed_static + len(killed_numeric)) / total
    assert rate >= 0.90, (
        f"combined kill rate {rate:.3f} "
        f"({killed_static}+{len(killed_numeric)}/{total})"
    )
    # the static analyzer alone must still do the overwhelming majority
    assert killed_static / total >= 0.85, (
        f"static kill rate {killed_static / total:.3f}; "
        f"numeric-only kills: {killed_numeric}"
    )


def test_pinned_mutants_are_killed():
    """One deterministic mutant per structural rule, pinned independent of
    the corpus rng (catches a rule regressing even if the rate holds)."""
    import dataclasses

    ssd = _ssd_base()
    summ = _summary_of(ssd)

    # dropped reset -> stale carried state
    _, m_summ, _ = mut_drop_reset(None, ssd, summ)
    r = analyze_call_site(ssd, summary=m_summ)
    assert _kinds(r) == {"scratch-no-reset"}

    # reset keyed on the outer axis
    _, m_summ, _ = mut_reset_axis_shift(None, ssd, summ)
    r = analyze_call_site(ssd, summary=m_summ)
    assert _kinds(r) == {"scratch-carry-axis"}

    # off-by-one grids
    short = dataclasses.replace(ssd, grid=(ssd.grid[0], ssd.grid[1] - 1))
    assert "coverage-gap" in _kinds(analyze_call_site(short))
    long = dataclasses.replace(ssd, grid=(ssd.grid[0], ssd.grid[1] + 1))
    assert {"oob-read", "oob-write"} <= _kinds(analyze_call_site(long))

    # corrupted output index map
    flash = _flash_base()
    bumped = flash.with_out_block(0, _mapped(
        flash.out_blocks[0],
        lambda c: (c[0], c[1] + 1, c[2])))
    assert "oob-write" in _kinds(analyze_call_site(bumped))


def test_known_survivor_class_is_what_interpret_tests_catch():
    """The triaged survivor class, pinned: zeroing an input index map keeps
    every read in bounds (analyzer-clean) but reads the wrong data — the
    interpret-mode parity sweep in test_kernels.py is the complementary
    oracle for exactly this."""
    rms = _rmsnorm_base()
    zeroed = rms.with_in_block(0, _mapped(
        rms.in_blocks[0], lambda c: (0,) * len(c)))
    r = analyze_call_site(zeroed)
    assert r.ok, str(r)  # structurally valid ...
    assert _fingerprint(zeroed, _summary_of(rms)) != _fingerprint(
        rms, _summary_of(rms))  # ... but genuinely different reads
