"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_reference
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_reference
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_reference


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ flash
@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 256, 4, 1, 128),    # MQA, MXU-aligned head dim
    (2, 384, 6, 3, 64),     # non-pow2 heads, S multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_reference(B, S, H, K, D, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=128, block_k=128, interpret=True)
    want = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_non_causal():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, interpret=True)
    want = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_blocks_divide_seq():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    k, v = q, q
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        want = attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 16, 16, 32),
    (2, 96, 3, 32, 64, 32),    # padding path (96 % 64 != 0 with chunk 64)
    (1, 256, 4, 64, 64, 64),
    (1, 64, 1, 128, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_matches_reference(B, S, H, P, N, chunk, dtype):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    la = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, H, N)) * 0.3, dtype)
    Cm = jnp.asarray(rng.normal(size=(B, S, H, N)) * 0.3, dtype)
    Y, fin = ssd_pallas(X, la, Bm, Cm, chunk=chunk, interpret=True)
    Yr, finr = ssd_reference(X, la, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(Y, np.float32), np.asarray(Yr, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(fin, np.float32), np.asarray(finr, np.float32), **_tol(dtype)
    )


def test_ssd_shared_bc_broadcast():
    rng = np.random.default_rng(3)
    B, S, H, P, N = 1, 128, 2, 16, 8
    X = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.3, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Y, fin = ssd_pallas(X, la, Bm, Cm, chunk=32, interpret=True)
    Yr, finr = ssd_reference(X, la, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(Y), np.asarray(Yr), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(8, 64), (3, 7, 128), (1, 1024), (513, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_reference(shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    w = jnp.asarray(rng.normal(size=shape[-1]) + 1.0, jnp.float32)
    got = rmsnorm_pallas(x, w, interpret=True)
    want = rmsnorm_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_rmsnorm_block_rows_sweep():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(300, 64)), jnp.float32)
    w = jnp.ones((64,), jnp.float32)
    for br in [1, 32, 256, 512]:
        got = rmsnorm_pallas(x, w, block_rows=br, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(rmsnorm_reference(x, w)), rtol=2e-6)


@pytest.mark.parametrize("shape", [(5, 100), (4, 33), (2, 3, 130), (1, 1)])
def test_rmsnorm_lane_unaligned_d(shape):
    """Feature dims off the 128-lane grid are zero-padded; dividing the
    square-sum by the true d keeps the numerics exact."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    w = jnp.asarray(rng.normal(size=shape[-1]) + 1.0, jnp.float32)
    got = rmsnorm_pallas(x, w, interpret=True)
    want = rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6, atol=2e-6)


def test_rmsnorm_degenerate_inputs_raise():
    w = jnp.ones((64,), jnp.float32)
    with pytest.raises(ValueError, match="no rows"):
        rmsnorm_pallas(jnp.zeros((0, 64), jnp.float32), w, interpret=True)
    with pytest.raises(ValueError, match="feature dim is 0"):
        rmsnorm_pallas(jnp.zeros((4, 0), jnp.float32),
                       jnp.ones((0,), jnp.float32), interpret=True)
    with pytest.raises(ValueError, match="weight size"):
        rmsnorm_pallas(jnp.zeros((4, 64), jnp.float32),
                       jnp.ones((32,), jnp.float32), interpret=True)


# ---------------------------------------- model-level kernel integration
def test_model_with_pallas_flash_matches_reference_path():
    """A reduced dense model in use_pallas mode (interpret) must match the
    jnp attention path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model, unbox

    cfg = get_config("chatglm3-6b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=2)
    batch = {"tokens": jnp.asarray(np.arange(2 * 128).reshape(2, 128) % cfg.vocab, jnp.int32)}

    model_ref = build_model(cfg)
    params = unbox(model_ref.init(jax.random.PRNGKey(0)))
    loss_ref, _ = model_ref.loss(params, batch)

    cfg_pl = dataclasses.replace(cfg, use_pallas=True)
    model_pl = build_model(cfg_pl)
    loss_pl, _ = model_pl.loss(params, batch)
    np.testing.assert_allclose(float(loss_ref), float(loss_pl), rtol=1e-4)
