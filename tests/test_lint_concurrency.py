"""Concurrency lint: rule-by-rule synthetic sources + a tree-wide clean run.

Each case feeds ``lint_module`` an in-memory module exercising exactly one
rule, so a regression is attributable to the rule that broke.  The final
test pins ``src/repro`` itself at zero findings — the lint gate CI runs.
"""

import textwrap

from repro.analysis.lint_concurrency import (
    INTERNALLY_LOCKED,
    SHARED_CACHE_REGISTRY,
    lint_module,
    lint_paths,
)


def _lint(src):
    return lint_module("<test>", source=textwrap.dedent(src))


def _rules(findings):
    return [f.rule for f in findings]


# ----------------------------------------------------------------- UG01


def test_ug01_unguarded_mutation_of_guarded_global():
    findings = _lint("""
        import threading
        _CACHE = {}
        _LOCK = threading.Lock()

        def guarded(k, v):
            with _LOCK:
                _CACHE[k] = v

        def unguarded(k):
            return _CACHE.setdefault(k, [])
    """)
    assert _rules(findings) == ["UG01"]
    assert findings[0].name == "_CACHE"
    assert findings[0].line  # attributable to the setdefault line


def test_ug01_registry_globals_always_need_guards():
    # names in SHARED_CACHE_REGISTRY must be guarded even if the module
    # never guards them anywhere (no "guarded-somewhere" evidence needed)
    name = sorted(SHARED_CACHE_REGISTRY)[0]
    findings = _lint(f"""
        {name} = {{}}

        def touch(k):
            {name}[k] = 1
    """)
    assert _rules(findings) == ["UG01"]


def test_ug01_clean_when_all_sites_guarded():
    findings = _lint("""
        import threading
        _CACHE = {}
        _LOCK = threading.Lock()

        def a(k, v):
            with _LOCK:
                _CACHE[k] = v

        def b(k):
            with _LOCK:
                return _CACHE.pop(k, None)
    """)
    assert findings == []


def test_ug01_internally_locked_method_calls_ok_rebind_not():
    name = sorted(INTERNALLY_LOCKED)[0]
    clean = _lint(f"""
        def use():
            return {name}.get("k")
    """)
    assert clean == []
    rebind = _lint(f"""
        def reset():
            global {name}
            {name} = {{}}
    """)
    assert _rules(rebind) == ["UG01"]


# ----------------------------------------------------------------- CG01


def test_cg01_unguarded_self_attr_mutation():
    findings = _lint("""
        import threading

        class Sess:
            def __init__(self):
                self._lock = threading.Lock()
                self._cache = {}

            def put(self, k, v):
                self._cache[k] = v
    """)
    assert _rules(findings) == ["CG01"]
    assert findings[0].name == "self._cache"


def test_cg01_init_exempt_and_guarded_clean():
    findings = _lint("""
        import threading

        class Sess:
            def __init__(self):
                self._lock = threading.Lock()
                self._cache = {}

            def put(self, k, v):
                with self._lock:
                    self._cache[k] = v
    """)
    assert findings == []


def test_cg01_annassign_attrs_detected():
    # ``self._cache: Dict = {}`` is an AnnAssign, not an Assign — the
    # original session.py findings depended on this path
    findings = _lint("""
        import threading
        from typing import Dict

        class Sess:
            def __init__(self):
                self._lock: threading.Lock = threading.Lock()
                self._cache: Dict = {}

            def put(self, k, v):
                self._cache[k] = v
    """)
    assert _rules(findings) == ["CG01"]


def test_cg01_silent_when_class_owns_no_lock():
    # classes with no locking intent are out of scope (single-threaded types)
    findings = _lint("""
        class Plain:
            def __init__(self):
                self._items = []

            def add(self, x):
                self._items.append(x)
    """)
    assert findings == []


# ------------------------------------------------------------ FA01/MD01


def test_fa01_function_attribute_state():
    findings = _lint("""
        def counter():
            counter.n = getattr(counter, "n", 0) + 1
            return counter.n
    """)
    assert _rules(findings) == ["FA01"]


def test_md01_mutable_default():
    findings = _lint("""
        def collect(x, acc=[]):
            acc.append(x)
            return acc
    """)
    assert _rules(findings) == ["MD01"]


def test_md01_none_default_clean():
    assert _lint("""
        def collect(x, acc=None):
            acc = acc or []
            acc.append(x)
            return acc
    """) == []


# ------------------------------------------------------------ suppression


def test_lint_ok_suppresses_single_line():
    findings = _lint("""
        import threading
        _CACHE = {}
        _LOCK = threading.Lock()

        def guarded(k, v):
            with _LOCK:
                _CACHE[k] = v

        def unguarded(k):
            return _CACHE.setdefault(k, [])  # lint-ok: benign race, idempotent
    """)
    assert findings == []


# -------------------------------------------------------------- the tree


def test_src_tree_is_lint_clean():
    """The gate CI enforces: zero findings across src/repro."""
    assert lint_paths(["src/repro"]) == []
