"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates its REDUCED config (same family/code paths,
tiny sizes) and runs, on CPU:
  * one forward/loss + gradient step (train_step semantics),
  * a prefill + two decode steps (serve_step semantics),
asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model, param_count, unbox

SMOKE_B, SMOKE_S = 2, 32


def _batch(cfg, B=SMOKE_B, S=SMOKE_S):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)}
    if cfg.vlm:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_img_tokens, cfg.d_model)), jnp.float32
        )
    if cfg.enc_dec:
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_dec.enc_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def _setup(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check the assigned numbers are wired through
    table = {
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    want = table[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == want


def test_train_step_smoke(arch):
    cfg, model, params = _setup(arch)
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # a plausible xent for random init: close to ln(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab) + 1
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


def test_prefill_and_decode_smoke(arch):
    cfg, model, params = _setup(arch)
    batch = _batch(cfg)
    logits, state = jax.jit(model.prefill)(params, batch)
    B = SMOKE_B
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(model.decode_step)
    for _ in range(2):
        logits, state = step(params, state, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def test_decode_matches_prefill_continuation(arch):
    """Teacher-forced decode after prefill must agree with a longer prefill
    (KV-cache / recurrent-state correctness)."""
    cfg, model, params = _setup(arch)
    full = _batch(cfg, S=SMOKE_S)
    short = dict(full)
    short["tokens"] = full["tokens"][:, : SMOKE_S - 2]

    logits_full, _ = jax.jit(model.prefill)(params, full)

    _, state = jax.jit(model.prefill)(params, short)
    step = jax.jit(model.decode_step)
    lg, state = step(params, state, full["tokens"][:, SMOKE_S - 2 : SMOKE_S - 1])
    lg, state = step(params, state, full["tokens"][:, SMOKE_S - 1 : SMOKE_S])

    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(lg[:, -1], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_param_count_sanity(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: unbox(model.init(k)), jax.random.PRNGKey(0))
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected = {
        "granite-20b": 20e9,
        "chatglm3-6b": 6e9,
        "mistral-large-123b": 123e9,
        "minitron-4b": 4e9,
        "xlstm-1.3b": 1.3e9,
        "internvl2-26b": 20e9,      # backbone only (ViT stubbed)
        "olmoe-1b-7b": 7e9,
        "deepseek-v2-lite-16b": 16e9,
        "whisper-small": 0.24e9,
        "zamba2-2.7b": 2.7e9,
    }[arch]
    assert 0.5 * expected < n < 1.8 * expected, f"{arch}: {n/1e9:.2f}B params"
