"""Overlap-aware reconfiguration planning: the per-link/overlappable cost
model (``cost_model.reconfig_cost``), its threading through all three
solvers, and the regression fixes that rode along (planner side channel,
shortest-path LRU, sim-backend shard shape, bounded plan cache)."""

import threading

import numpy as np
import pytest
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import cost_model as C
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.cost_model import reconfig_cost
from repro.core.planner import (
    _round_costs,
    build_states,
    plan,
    plan_bruteforce,
    plan_milp,
)

HW = C.H100_DGX
MB = 1024.0 ** 2


def _std(n):
    std = T.standard_topologies(n)
    return [std["ring"], std["torus2d"]]


def _sched(collective, n, buf):
    algo = {"all_to_all": "dex"}.get(collective, "rhd")
    return S.get_schedule(collective, algo, n, buf)


def _modes(r):
    base = HW.with_reconfig(r)
    return {
        "serial": base,
        "partial": base.with_link_reconfig(r / 16),
        "overlap": base.with_link_reconfig(r / 16, overlap=True),
    }


# ------------------------------------------------------------ reconfig_cost
def test_reconfig_cost_identity_is_free():
    for hw in _modes(1e-3).values():
        assert reconfig_cost(T.ring(8), T.ring(8), hw) == 0.0


def test_reconfig_cost_serial_charges_full_delay_on_any_change():
    hw = HW.with_reconfig(1e-3)
    assert hw.reconfig_mode == "serial"
    assert reconfig_cost(T.ring(8), T.hypercube(8), hw) == 1e-3
    # even a single-circuit delta pays the full fabric delay in serial mode
    near = T.Topology(8, frozenset(set(T.ring(8).edges) - {(0, 1)}))
    assert reconfig_cost(T.ring(8), near, hw) == 1e-3


def test_reconfig_cost_partial_counts_changed_directed_circuits():
    hw = HW.with_reconfig(1e-3).with_link_reconfig(1e-6)
    assert hw.reconfig_mode == "partial"
    ring = T.ring(8)
    near = T.Topology(8, frozenset(set(ring.edges) - {(0, 1)}))
    assert reconfig_cost(ring, near, hw) == pytest.approx(1e-6)  # one circuit
    assert reconfig_cost(near, ring, hw) == pytest.approx(1e-6)  # symmetric
    k = len(ring.edges ^ T.hypercube(8).edges)
    assert reconfig_cost(ring, T.hypercube(8), hw) == pytest.approx(k * 1e-6)


def test_reconfig_cost_caps_at_full_fabric_delay():
    hw = HW.with_reconfig(1e-5).with_link_reconfig(1e-3)  # r_link >> r_full
    assert reconfig_cost(T.ring(8), T.hypercube(8), hw) == pytest.approx(1e-5)


def test_hw_param_helpers_and_mode():
    hw = HW.with_link_reconfig(1e-6, overlap=True)
    assert hw.reconfig_mode == "overlap"
    assert hw.reconfig_delay == HW.reconfig_delay  # cap preserved
    assert HW.with_overlap().reconfig_mode == "overlap"
    assert HW.reconfig_mode == "serial"  # defaults unchanged


# ----------------------------------------------- solver agreement, all modes
@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize(
    "collective", ["reduce_scatter", "all_gather", "all_reduce", "all_to_all"]
)
@pytest.mark.parametrize("mode", ["partial", "overlap"])
def test_dp_bruteforce_milp_agree_in_new_modes(n, collective, mode):
    hw = _modes(5e-4)[mode]
    for topo_name in ("ring", "grid2d"):
        g0 = T.standard_topologies(n)[topo_name]
        for buf in (64 * 1024.0, 256 * MB):
            sched = _sched(collective, n, buf)
            p = plan(g0, _std(n), sched, hw)
            bf = plan_bruteforce(g0, _std(n), sched, hw)
            m = plan_milp(g0, _std(n), sched, hw)
            assert p.total_cost == pytest.approx(bf, rel=1e-12), (topo_name, buf)
            assert p.total_cost == pytest.approx(m, rel=1e-9), (topo_name, buf)


def test_overlap_le_partial_le_serial_everywhere():
    """Pointwise-cheaper transitions + exact planner ⇒ ordered optima."""
    for n in (8, 16):
        for r in (5e-6, 5e-4, 1e-3):
            modes = _modes(r)
            for topo_name in ("ring", "torus2d", "grid2d"):
                g0 = T.standard_topologies(n)[topo_name]
                for coll in ("reduce_scatter", "all_to_all"):
                    for buf in (1 * MB, 256 * MB):
                        sched = _sched(coll, n, buf)
                        cs = {
                            mode: plan(g0, _std(n), sched, hw).total_cost
                            for mode, hw in modes.items()
                        }
                        assert cs["partial"] <= cs["serial"] + 1e-15
                        assert cs["overlap"] <= cs["partial"] + 1e-15


def test_overlap_strictly_wins_in_mems_regime():
    """r = 1 ms, large buffer: hiding reprogramming behind the previous
    round's communication must beat the serial planner strictly."""
    n, buf, r = 8, 256 * MB, 1e-3
    sched = _sched("reduce_scatter", n, buf)
    serial_hw = HW.with_reconfig(r)
    # r_link scaled so a full-fabric swap (≈4n changed circuits) costs r
    over_hw = serial_hw.with_link_reconfig(r / (4 * n), overlap=True)
    serial = plan(T.ring(n), _std(n), sched, serial_hw).total_cost
    over = plan(T.ring(n), _std(n), sched, over_hw).total_cost
    assert over < serial * 0.999


def test_round0_reconfig_is_never_hidden():
    """The reconfiguration out of G0 has no previous round to hide behind:
    a one-round schedule must price overlap == partial."""
    n, buf = 8, 16 * MB
    sched = S.Schedule(
        "all_to_all", "direct", n, buf, (S.dex_all_to_all(n, buf).rounds[0],)
    )
    base = HW.with_reconfig(5e-4)
    partial = plan(T.grid2d(2, 4), _std(n), sched, base.with_link_reconfig(5e-4 / 16))
    over = plan(
        T.grid2d(2, 4), _std(n), sched,
        base.with_link_reconfig(5e-4 / 16, overlap=True),
    )
    assert over.total_cost == pytest.approx(partial.total_cost, rel=1e-12)


def test_overlap_plan_breakdown_sums_to_total():
    n, buf = 16, 256 * MB
    hw = _modes(1e-3)["overlap"]
    p = plan(T.grid2d(4, 4), _std(n), S.rhd_reduce_scatter(n, buf), hw)
    b = p.breakdown()
    assert b["total"] == pytest.approx(
        b["alpha"] + b["beta"] + b["dilation"] + b["congestion"] + b["reconfig"]
    )


def test_serial_defaults_bit_identical_to_reference_recurrence():
    """With the new fields at their defaults the DP must still equal the
    paper's closed-form best cases exactly (the pre-PR behavior)."""
    n, buf = 128, 256e6
    p = plan(T.ring(n), _std(n), S.rhd_reduce_scatter(n, buf), HW)
    assert p.num_reconfigs == 7
    assert p.total_cost == pytest.approx(
        C.ideal_cost(S.rhd_reduce_scatter(n, buf), HW) + 7 * HW.reconfig_delay
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([4, 8]),
    buf=st.floats(min_value=1e3, max_value=1e9),
    r=st.floats(min_value=1e-7, max_value=1e-2),
    link_div=st.sampled_from([4, 16, 64]),
    topo=st.sampled_from(["ring", "torus2d", "grid2d", "hypercube"]),
    coll=st.sampled_from(["reduce_scatter", "all_reduce", "all_to_all"]),
)
def test_property_overlapped_never_costs_more_than_serial(
    n, buf, r, link_div, topo, coll
):
    g0 = T.standard_topologies(n)[topo]
    sched = _sched(coll, n, buf)
    serial_hw = HW.with_reconfig(r)
    over_hw = serial_hw.with_link_reconfig(r / link_div, overlap=True)
    serial = plan(g0, _std(n), sched, serial_hw).total_cost
    over = plan(g0, _std(n), sched, over_hw).total_cost
    assert over <= serial + 1e-15
    # and the overlapped DP stays exact
    assert over == pytest.approx(plan_bruteforce(g0, _std(n), sched, over_hw),
                                 rel=1e-12)


# ------------------------------------------------------- satellite: planner
def test_round_costs_has_no_function_attribute_side_channel():
    """Regression: costs are returned, not stashed on the function object
    (plan_bruteforce/plan_milp used to clobber plan()'s copy; concurrent
    sessions raced on it)."""
    n = 8
    sched = S.rhd_reduce_scatter(n, 1 * MB)
    states = build_states(T.ring(n), _std(n), sched)
    out = _round_costs(states, sched, HW)
    assert isinstance(out, tuple) and len(out) == 2
    cost, objs = out
    assert cost.shape == (len(sched.rounds), len(states))
    assert objs[(0, 0)].total == cost[0, 0]
    plan(T.ring(n), _std(n), sched, HW)
    plan_bruteforce(T.ring(n), _std(n), sched, HW)
    assert not hasattr(_round_costs, "last_objs")


# ------------------------------------------------------ satellite: SP cache
def test_sp_cache_is_bounded_lru_and_keeps_hot_entry():
    from repro.core.cost_model import _SP_CACHE, _SP_CACHE_MAX, _scipy_paths

    hot = T.fully_connected(6)  # no fast path: exercises the scipy cache
    hot_key = (hot.n, hot.edges)
    _scipy_paths(hot)
    for i in range(_SP_CACHE_MAX + 10):
        edges = frozenset({(0, 1), (1, 2), (2, 0), (0, i % 3 + 3)})
        _scipy_paths(T.Topology(7, edges, name=f"t{i}"))
        _scipy_paths(hot)  # touch: must survive the whole sweep
    assert len(_SP_CACHE) <= _SP_CACHE_MAX
    assert hot_key in _SP_CACHE


def test_sp_cache_thread_safety_smoke():
    from repro.core.cost_model import _scipy_paths

    errs = []

    def worker(seed):
        try:
            for i in range(40):
                k = (seed * 40 + i) % 90
                edges = frozenset({(0, 1), (1, 2), (2, 3), (3, 0), (0, k % 4 + 4)})
                dist, _ = _scipy_paths(T.Topology(9, edges))
                assert dist.shape == (9, 9)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs


# --------------------------------------------------- satellite: sim backend
def test_sim_reduce_scatter_rejects_indivisible_leading_dim():
    from repro.api import PcclSession
    from repro.comm.errors import ScheduleExecutionError

    comm = PcclSession(HW, thread_fabric=False).communicator(
        "x", 8, backend="sim"
    )
    with pytest.raises(ScheduleExecutionError, match="not divisible"):
        comm.reduce_scatter(np.ones((10, 4), np.float32))
    with pytest.raises(ScheduleExecutionError, match="not divisible"):
        comm.all_to_all(np.ones((10, 4), np.float32))
    assert comm.backend.events == []  # nothing charged on the error path
    shard = comm.reduce_scatter(np.ones((16, 4), np.float32))
    assert shard.shape == (2, 4)  # rank 0's placeholder shard


def test_sim_error_matches_interp_error_type():
    from repro.comm import ScheduleExecutionError as exported
    from repro.comm.errors import ScheduleExecutionError
    from repro.comm.primitives import ScheduleExecutionError as interp_err

    assert exported is ScheduleExecutionError is interp_err
    assert issubclass(ScheduleExecutionError, ValueError)


# ----------------------------------------------------- satellite: plan cache
def test_plan_cache_lru_bound_and_eviction_counter():
    from repro.api import PcclSession

    s = PcclSession(HW, g0=T.ring(8), thread_fabric=False, max_cached_plans=3)
    for i in range(5):
        s.plan("reduce_scatter", (i + 1) * MB)
    st_ = s.stats
    assert st_.size == 3 and st_.evictions == 2 and st_.misses == 5
    # most recent keys survived: re-planning them hits
    s.plan("reduce_scatter", 5 * MB)
    assert s.stats.hits == 1
    # the evicted oldest key re-plans (miss), not a stale hit
    s.plan("reduce_scatter", 1 * MB)
    assert s.stats.misses == 6


def test_plan_cache_lookup_refreshes_recency():
    from repro.api import PlanCache

    cache = PlanCache(max_entries=2)
    cache.store("a", "plan_a")
    cache.store("b", "plan_b")
    assert cache.lookup("a") == "plan_a"  # refresh a
    cache.store("c", "plan_c")            # evicts b, not a
    assert cache.lookup("a") == "plan_a"
    assert cache.lookup("b") is None
    assert cache.stats.evictions == 1
    with pytest.raises(ValueError):
        PlanCache(max_entries=0)


# -------------------------------------------------- session × overlap modes
def test_session_surfaces_reconfig_mode_and_warm_partial_reconfigs():
    from repro.api import PcclSession

    hw = HW.with_reconfig(5e-4).with_link_reconfig(5e-4 / 64, overlap=True)
    s = PcclSession(hw, g0=T.grid2d(4, 8))
    assert s.reconfig_mode == "overlap"
    cold = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    warm = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    assert warm.cost <= cold.cost + 1e-15
    # warm start re-enters the fabric's own ring for free
    assert warm.num_reconfigs == 0


def test_session_overlap_cost_le_serial_session_cost():
    from repro.api import PcclSession

    r = 1e-3
    serial_s = PcclSession(HW.with_reconfig(r), g0=T.ring(16))
    over_s = PcclSession(
        HW.with_reconfig(r).with_link_reconfig(r / 64, overlap=True),
        g0=T.ring(16),
    )
    for nb in (1 * MB, 64 * MB, 256 * MB):
        assert (
            over_s.plan("reduce_scatter", nb, algorithm="auto").cost
            <= serial_s.plan("reduce_scatter", nb, algorithm="auto").cost + 1e-15
        )
