import pytest

from repro.core import cost_model as C
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.pccl import (
    CollectiveRequest,
    baseline_cost,
    candidate_algorithms,
    choose_algorithm,
    plan_collective,
    theoretical_cost,
)

HW = C.H100_DGX


def test_paper_default_inputs():
    assert candidate_algorithms("reduce_scatter", 128, "paper_default") == ["rhd"]
    assert candidate_algorithms("all_to_all", 128, "paper_default") == ["dex"]
    assert candidate_algorithms("reduce_scatter", 12, "paper_default") == ["ring"]


def test_plan_collective_reduce_scatter_matches_planner():
    req = CollectiveRequest("reduce_scatter", 32, 64e6)
    p = plan_collective(req, T.ring(32), HW)
    assert p.algorithm == "rhd"
    assert p.cost <= baseline_cost("reduce_scatter", "rhd", T.ring(32), 32, 64e6, HW).total


def test_auto_mode_picks_cheaper_algorithm_by_size():
    """§2.2: latency-optimal for small buffers, bandwidth-optimal for large.
    On ideal (reconfigurable) fabric, RHD dominates ring for RS at both ends
    (same β, lower α) — but for AllToAll the DEX/direct crossover is real."""
    n = 64
    small = choose_algorithm("all_to_all", n, 4 * 1024, HW)
    large = choose_algorithm("all_to_all", n, 1024 ** 3, HW)
    assert small == "dex"
    assert large == "direct"


def test_pccl_only_system_optimal_on_all_topologies():
    """Fig. 7 headline: PCCL is optimal on ALL starting topologies; every
    fixed algorithm is beaten somewhere."""
    n, buf = 32, 256e6
    topos = T.standard_topologies(n)
    for name, g0 in topos.items():
        p = plan_collective(CollectiveRequest("reduce_scatter", n, buf), g0, HW)
        for algo in ("ring", "rhd"):
            fixed = baseline_cost("reduce_scatter", algo, g0, n, buf, HW).total
            assert p.cost <= fixed + 1e-12, (name, algo)


def test_theoretical_cost_helper():
    n, buf = 16, 1e6
    assert theoretical_cost("reduce_scatter", "rhd", n, buf, HW) == pytest.approx(
        sum(HW.alpha + HW.beta * r.size for r in S.rhd_reduce_scatter(n, buf).rounds)
    )


def test_candidates_recorded():
    req = CollectiveRequest("all_to_all", 16, 1e6, algorithm="auto")
    p = plan_collective(req, T.ring(16), HW)
    assert {a for a, _ in p.candidates} == {"dex", "direct"}
