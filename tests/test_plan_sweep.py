"""Structure/numeric planner split: batched sweeps, cached routing factors,
memoized transitions, and the session's two-level cache.

The load-bearing guarantee: ``plan_sweep`` is the *same* DP as a per-size
``plan`` loop — bit-identical totals, step sequences, and tie-breaking —
across every collective and reconfiguration mode.  Everything else here
pins the caches that make the split fast (structure table, transition memo,
round dedup) and the fast routing paths against the scipy general path.
"""

import random

import numpy as np
import pytest
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.api import PcclSession
from repro.core import cost_model as C
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.pccl import CollectiveRequest, plan_collective, plan_collective_sweep
from repro.core.planner import (
    _round_costs,
    _transition_costs,
    build_states,
    build_structure,
    clear_planner_caches,
    plan,
    plan_sweep,
)

HW = C.H100_DGX
MB = 1024.0 ** 2
SIZES = [64 * 1024.0, 1 * MB, 32 * MB, 1024.0 ** 3]

MODES = {
    "serial": HW,
    "partial": HW.with_link_reconfig(HW.reconfig_delay / 64),
    "overlap": HW.with_link_reconfig(HW.reconfig_delay / 64, overlap=True),
}

COLLECTIVES = [
    ("reduce_scatter", "rhd"),
    ("all_gather", "ring"),
    ("all_reduce", "rhd"),
    ("all_to_all", "dex"),
]


def _std(n):
    return [T.ring(n), T.torus2d(*T.square_dims2(n))]


def _assert_plans_bit_identical(a, b):
    assert a.total_cost == b.total_cost  # exact, not approx
    assert [s.state_idx for s in a.steps] == [s.state_idx for s in b.steps]
    assert [s.reconfigured for s in a.steps] == [s.reconfigured for s in b.steps]
    assert [s.total for s in a.steps] == [s.total for s in b.steps]
    assert [s.cost.total for s in a.steps] == [s.cost.total for s in b.steps]
    assert a.final_topology.edges == b.final_topology.edges


# ------------------------------------------------------ sweep == plan loop
@pytest.mark.parametrize("n", [8, 16])
@pytest.mark.parametrize("mode", list(MODES))
def test_sweep_bit_identical_to_plan_loop(n, mode):
    """Acceptance: plan_sweep ≡ per-size plan() across collectives × modes."""
    hw = MODES[mode]
    g0 = T.grid2d(*T.square_dims2(n))
    std = _std(n)
    for coll, algo in COLLECTIVES:
        scheds = [S.get_schedule(coll, algo, n, d) for d in SIZES]
        loop = [plan(g0, std, sch, hw) for sch in scheds]
        swept = plan_sweep(g0, std, scheds[0], hw, SIZES, schedules=scheds)
        for a, b in zip(loop, swept):
            _assert_plans_bit_identical(a, b)


def test_sweep_default_rescale_pow2_ratios_bit_identical():
    """Without explicit schedules, the sweep rescales its template; for
    power-of-two size ratios that is exactly the generator arithmetic."""
    n = 16
    sizes = [1 * MB, 2 * MB, 8 * MB, 64 * MB, 1024 * MB]
    g0 = T.ring(n)
    for coll, algo in COLLECTIVES:
        loop = [plan(g0, _std(n), S.get_schedule(coll, algo, n, d), HW)
                for d in sizes]
        template = S.get_schedule(coll, algo, n, sizes[0])
        swept = plan_sweep(g0, _std(n), template, HW, sizes)
        for a, b in zip(loop, swept):
            _assert_plans_bit_identical(a, b)


def test_facade_sweep_matches_plan_collective_per_size():
    sizes = [1 * MB, 4 * MB, 32 * MB, 512 * MB]
    for n in (8, 16):
        g0 = T.ring(n)
        req = CollectiveRequest("reduce_scatter", n, sizes[0], algorithm="auto")
        swept = plan_collective_sweep(req, sizes, g0, HW)
        for d, p in zip(sizes, swept):
            q = plan_collective(
                CollectiveRequest("reduce_scatter", n, d, algorithm="auto"), g0, HW
            )
            assert p.cost == q.cost
            assert p.algorithm == q.algorithm
            assert p.candidates == q.candidates
            assert p.request.buffer_bytes == d


def test_sweep_rejects_mismatched_schedules():
    n = 8
    rs = S.ring_reduce_scatter(n, 1 * MB)
    with pytest.raises(ValueError):
        plan_sweep(T.ring(n), _std(n), rs, HW, [1 * MB, 2 * MB],
                   schedules=[rs])  # wrong length
    other = S.rhd_reduce_scatter(n, 2 * MB)
    with pytest.raises(ValueError):
        plan_sweep(T.ring(n), _std(n), rs, HW, [1 * MB, 2 * MB],
                   schedules=[rs, other])  # different round structure


def test_sweep_rejects_structure_built_from_other_schedule():
    """A caller-supplied structure is validated against the template even on
    the default (rescaled-schedules) path — a mismatch must raise, not
    silently price the wrong (D, C) matrices."""
    n = 8
    foreign = build_structure(
        T.ring(n), _std(n), S.ring_all_gather(n, 1 * MB), HW
    )
    rs = S.ring_reduce_scatter(n, 1 * MB)  # same round count, different pairs?
    # ring AG and ring RS share the same pair multiset, so use a genuinely
    # different structure: direct all-to-all (7 rounds too, distinct pairs)
    a2a = S.direct_all_to_all(n, 1 * MB)
    with pytest.raises(ValueError):
        plan_sweep(T.ring(n), _std(n), a2a, HW, [1 * MB], structure=foreign)
    # stale provenance is rejected too: the transition table bakes in the
    # build-time reconfig params, g0_idx the build-time fabric
    ag = S.ring_all_gather(n, 1 * MB)
    with pytest.raises(ValueError):
        plan_sweep(T.ring(n), _std(n), ag, HW.with_link_reconfig(1e-7),
                   [1 * MB], structure=foreign)
    with pytest.raises(ValueError):
        plan_sweep(T.grid2d(2, 4), _std(n), ag, HW, [1 * MB],
                   structure=foreign)
    # matching template still works
    plans = plan_sweep(T.ring(n), _std(n), rs, HW, [1 * MB],
                       structure=build_structure(T.ring(n), _std(n), rs, HW))
    assert plans[0].total_cost == plan(T.ring(n), _std(n), rs, HW).total_cost


def test_sweep_empty_schedule():
    n = 8
    empty = S.Schedule("all_reduce", "ring", n, 0.0, ())
    plans = plan_sweep(T.ring(n), _std(n), empty, HW, [1.0, 2.0])
    assert len(plans) == 2
    assert all(p.total_cost == 0.0 and p.final_topology.edges == T.ring(n).edges
               for p in plans)


# --------------------------------------------------- session two-level cache
def test_session_plan_sweep_matches_cold_plans_and_feeds_cache():
    sizes = [1 * MB, 2 * MB, 16 * MB, 256 * MB]  # pow2 ratios: exact
    ref = PcclSession(HW, g0=T.grid2d(4, 8), thread_fabric=False)
    loop = [ref.plan("reduce_scatter", d, algorithm="auto") for d in sizes]

    s = PcclSession(HW, g0=T.grid2d(4, 8), thread_fabric=False)
    swept = s.plan_sweep("reduce_scatter", sizes, algorithm="auto")
    for a, b in zip(loop, swept):
        assert a.cost == b.cost and a.algorithm == b.algorithm
        _assert_plans_bit_identical(a.plan, b.plan)

    # sweep populated the per-nbytes plan cache: plan() now hits
    hits0 = s.stats.hits
    again = s.plan("reduce_scatter", sizes[2], algorithm="auto")
    assert again is swept[2]
    assert s.stats.hits == hits0 + 1

    # and plan() results flow back into a later sweep
    pre = s.stats.misses
    swept2 = s.plan_sweep("reduce_scatter", sizes, algorithm="auto")
    assert all(a is b for a, b in zip(swept, swept2))
    assert s.stats.misses == pre


def test_structure_cache_hit_on_new_size():
    s = PcclSession(HW, g0=T.ring(16), thread_fabric=False)
    s.plan("reduce_scatter", 4 * MB, algorithm="auto")
    assert s.structure_stats.misses == 1 and s.structure_stats.hits == 0
    s.plan("reduce_scatter", 8 * MB, algorithm="auto")  # new size: plan miss
    assert s.stats.misses == 2
    assert s.structure_stats.hits == 1  # ...but the structures were reused
    # different collective: new structure entry
    s.plan("all_gather", 4 * MB, algorithm="auto")
    assert s.structure_stats.misses == 2


def test_sweep_does_not_thread_fabric():
    s = PcclSession(HW, g0=T.grid2d(4, 4), thread_fabric=True)
    before = s.fabric(16).edges
    s.plan_sweep("reduce_scatter", [1 * MB, 32 * MB], algorithm="ring")
    assert s.fabric(16).edges == before  # sweeps price alternatives only
    p = s.plan("reduce_scatter", 1 * MB, algorithm="ring")
    assert s.fabric(16).edges == p.final_topology.edges


# ------------------------------------------------------------- round dedup
def test_round_costs_dedups_structurally_identical_rounds():
    """Satellite: plain plan() on ring schedules routes the shared pair set
    once, not n−1 times."""
    n = 8
    sched = S.ring_reduce_scatter(n, 1 * MB)  # 7 rounds, one pair multiset
    states = build_states(T.grid2d(2, 4), _std(n), sched)
    clear_planner_caches()
    base = C.STRUCTURE_TABLE.stats.routing_calls
    cost, objs = _round_costs(states, sched, HW)
    routed = C.STRUCTURE_TABLE.stats.routing_calls - base
    assert routed <= len(states)  # one routing query per state, not per round
    # identical rounds share rows and RoundCost objects
    assert np.array_equal(cost[0], cost[1])
    for s in states:
        assert objs[(0, s.idx)] is objs[(1, s.idx)]
    # a second identical call is served entirely from the structure table
    base = C.STRUCTURE_TABLE.stats.routing_calls
    _round_costs(states, sched, HW)
    assert C.STRUCTURE_TABLE.stats.routing_calls == base


def test_structure_phase_routes_once_per_distinct_round():
    n = 8
    sched = S.ring_reduce_scatter(n, 1 * MB)
    clear_planner_caches()
    base = C.STRUCTURE_TABLE.stats.routing_calls
    structure = build_structure(T.grid2d(2, 4), _std(n), sched, HW)
    routed = C.STRUCTURE_TABLE.stats.routing_calls - base
    assert routed <= len(structure.states)
    assert structure.dilation.shape == (n - 1, len(structure.states))
    # rows of structurally identical rounds are equal
    assert np.array_equal(structure.dilation[0], structure.dilation[-1])


# -------------------------------------------------------- transition memo
def test_transition_costs_memoized_and_vectorized():
    """Satellite: same (states, hw) returns the cached matrix; entries match
    the scalar reconfig_cost; cache distinguishes reconfig params."""
    n = 8
    sched = S.rhd_reduce_scatter(n, 1 * MB)
    states = build_states(T.ring(n), _std(n), sched)
    for hw in (HW, HW.with_link_reconfig(HW.reconfig_delay / 16)):
        t1 = _transition_costs(states, hw)
        t2 = _transition_costs(states, hw)
        assert t1 is t2  # memo hit returns the shared read-only array
        assert not t1.flags.writeable
        for p in states:
            for s in states:
                want = 0.0 if p.idx == s.idx else C.reconfig_cost(p.topo, s.topo, hw)
                assert t1[p.idx, s.idx] == want
    assert not np.array_equal(
        _transition_costs(states, HW),
        _transition_costs(states, HW.with_link_reconfig(HW.reconfig_delay / 16)),
    )


# ------------------------------------------------------- routing fast paths
def _random_linear_topo(rng, n):
    nodes = list(range(n))
    rng.shuffle(nodes)
    edges = set()
    i = 0
    while i < n - 1:
        seg = rng.randrange(1, 5)
        chunk = nodes[i:i + seg + 1]
        for a, b in zip(chunk, chunk[1:]):
            edges.add((a, b))
        if rng.random() < 0.5 and len(chunk) > 2:
            edges.add((chunk[-1], chunk[0]))
        i += seg + 1
    return T.Topology(n, frozenset(edges))


def _random_functional_topo(rng, n):
    edges = set()
    for u in range(n):
        if rng.random() < 0.8:
            v = rng.randrange(n)
            if v != u:
                edges.add((u, v))
    return T.Topology(n, frozenset(edges))


def _random_pairs(rng, n):
    pairs = [(rng.randrange(n), rng.randrange(n)) for _ in range(rng.randrange(1, 2 * n))]
    return [(a, b) for a, b in pairs if a != b]


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       family=st.sampled_from(["linear", "functional", "direct"]))
def test_property_fast_paths_agree_with_general_path(seed, family):
    """Satellite: direct-circuit, linear, and functional-graph fast paths
    all agree with the scipy general path on randomized topologies/rounds."""
    rng = random.Random(seed)
    n = rng.randrange(4, 12)
    if family == "linear":
        topo = _random_linear_topo(rng, n)
        pairs = _random_pairs(rng, n)
    elif family == "functional":
        topo = _random_functional_topo(rng, n)
        pairs = _random_pairs(rng, n)
    else:  # a round priced on its own ideal graph: every pair a circuit
        pairs = _random_pairs(rng, n)
        if not pairs:
            return
        topo = T.from_transfers(n, pairs)
    if not pairs:
        return
    fast = C._route_pairs(topo, pairs, allow_fast=True)
    general = C._route_pairs(topo, pairs, allow_fast=False)
    assert fast == general


def test_batched_linear_routing_matches_scalar_and_general():
    """The structure phase's batched router ≡ scalar fast path ≡ scipy."""
    rng = random.Random(7)
    for _ in range(60):
        n = rng.randrange(4, 14)
        topos = [_random_linear_topo(rng, n) for _ in range(rng.randrange(2, 6))]
        labels = [C._linear_labels(t) for t in topos]
        assert all(lab is not None for lab in labels)
        stacked = C._StackedLinear(labels)
        pairs = _random_pairs(rng, n)
        if not pairs:
            continue
        srcs = np.asarray([p[0] for p in pairs])
        dsts = np.asarray([p[1] for p in pairs])
        bd, bc, bf = C._route_linear_batch(stacked, srcs, dsts)
        for i, topo in enumerate(topos):
            batch = (int(bd[i]), int(bc[i]), bool(bf[i]))
            assert batch == C._route_pairs(topo, pairs, allow_fast=True)
            assert batch == C._route_pairs(topo, pairs, allow_fast=False)


def test_structure_table_accounting_and_clear():
    clear_planner_caches()
    topo = T.ring(8)
    rnd = S.ring_reduce_scatter(8, 1 * MB).rounds[0]
    assert C.round_factors(topo, rnd) == (1, 1, True)
    st1 = C.STRUCTURE_TABLE.stats
    assert (st1.misses, st1.hits) == (1, 0)
    assert C.round_factors(topo, rnd) == (1, 1, True)
    st2 = C.STRUCTURE_TABLE.stats
    assert (st2.misses, st2.hits) == (1, 1)
    assert st2.routing_calls == 1
    clear_planner_caches()
    assert C.STRUCTURE_TABLE.stats.size == 0
