import time

import pytest
from conftest import hypothesis_or_stubs

# real hypothesis when installed; otherwise only the property tests skip
given, settings, st = hypothesis_or_stubs()

from repro.core import cost_model as C
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.planner import build_states, plan, plan_bruteforce, plan_milp

HW = C.H100_DGX


def _std(n):
    std = T.standard_topologies(n)
    return [std["ring"], std["torus2d"]]


# ------------------------------------------------------------------ exactness
@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("buf", [64 * 1024.0, 256e6])
@pytest.mark.parametrize("topo_name", ["ring", "torus2d", "grid2d"])
def test_dp_matches_bruteforce(n, buf, topo_name):
    g0 = T.standard_topologies(n)[topo_name]
    sched = S.rhd_reduce_scatter(n, buf)
    p = plan(g0, _std(n), sched, HW)
    bf = plan_bruteforce(g0, _std(n), sched, HW)
    assert p.total_cost == pytest.approx(bf, rel=1e-12)


@pytest.mark.parametrize("r", [5e-6, 1e-3])
def test_dp_matches_milp(r):
    n, buf = 8, 1e8
    hw = HW.with_reconfig(r)
    g0 = T.ring(n)
    sched = S.rhd_reduce_scatter(n, buf)
    p = plan(g0, _std(n), sched, hw)
    m = plan_milp(g0, _std(n), sched, hw)
    assert p.total_cost == pytest.approx(m, rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8]),
    buf=st.floats(min_value=1e3, max_value=1e9),
    r=st.floats(min_value=1e-7, max_value=1e-2),
    topo=st.sampled_from(["ring", "torus2d", "grid2d", "hypercube"]),
    algo=st.sampled_from(["rhd", "ring", "dex"]),
)
def test_property_dp_optimal(n, buf, r, topo, algo):
    hw = HW.with_reconfig(r)
    g0 = T.standard_topologies(n)[topo]
    if algo == "dex":
        sched = S.dex_all_to_all(n, buf)
    elif algo == "ring":
        sched = S.ring_reduce_scatter(n, buf)
    else:
        sched = S.rhd_reduce_scatter(n, buf)
    p = plan(g0, _std(n), sched, hw)
    bf = plan_bruteforce(g0, _std(n), sched, hw)
    assert p.total_cost == pytest.approx(bf, rel=1e-12)


# -------------------------------------------------------- paper behaviours
def test_reconfigures_every_round_at_5us_128gpus():
    """Fig. 8: with r = 5 µs and a 256 MB buffer, PCCL reconfigures
    log2(128) = 7 times for RHD ReduceScatter."""
    n, buf = 128, 256e6
    g0 = T.ring(n)
    sched = S.rhd_reduce_scatter(n, buf)
    p = plan(g0, _std(n), sched, HW)
    assert p.num_reconfigs == 7
    # and achieves the textbook cost + 7 reconfigs
    assert p.total_cost == pytest.approx(C.ideal_cost(sched, HW) + 7 * HW.reconfig_delay)


def test_fewer_reconfigs_at_1ms():
    """Fig. 9: at r = 1 ms PCCL stops reconfiguring every round and eats
    congestion/dilation instead."""
    n, buf = 128, 1024 ** 3
    g0 = T.ring(n)
    sched = S.rhd_reduce_scatter(n, buf)
    hw = C.H100_DGX_R1MS
    p = plan(g0, _std(n), sched, hw)
    assert p.num_reconfigs < 7
    # never worse than the no-reconfig fixed cost or the always-reconfig cost
    fixed = C.schedule_cost_fixed(g0, sched, hw).total
    always = C.ideal_cost(sched, hw) + len(sched.rounds) * hw.reconfig_delay
    assert p.total_cost <= fixed + 1e-15
    assert p.total_cost <= always + 1e-15


def test_plan_on_ideal_start_needs_no_reconfig():
    """Ring RS on a ring fabric: every round's ideal graph == the directed
    ring ⊂ G0... the planner should keep G0 and pay nothing extra."""
    n, buf = 16, 1e8
    g0 = T.ring(n)
    sched = S.ring_reduce_scatter(n, buf)
    p = plan(g0, _std(n), sched, HW)
    assert p.num_reconfigs == 0
    assert p.total_cost == pytest.approx(C.ideal_cost(sched, HW))


def test_ring_schedule_ideal_graphs_dedupe():
    """All ring RS rounds share one ideal graph — dedup means staying on it
    costs a single reconfiguration, not one per round."""
    n = 8
    sched = S.ring_reduce_scatter(n, 1e6)
    states = build_states(T.grid2d(2, 4), _std(n), sched)
    ideal_states = [s for s in states if s.entry_rounds]
    assert len(ideal_states) == 1
    assert len(ideal_states[0].entry_rounds) == n - 1


def test_planner_beats_or_matches_best_fixed_everywhere():
    """Key takeaway #1: PCCL ≥ best algorithm on every starting topology."""
    n, buf = 32, 64e6
    for name, g0 in T.standard_topologies(n).items():
        sched = S.rhd_reduce_scatter(n, buf)
        p = plan(g0, _std(n), sched, HW)
        fixed = C.schedule_cost_fixed(g0, sched, HW).total
        assert p.total_cost <= fixed + 1e-15, name


def test_planner_runtime_under_one_second_128():
    """§4.1: 'PCCL's optimization can be solved in less than one second for
    the largest scale-up domains.'"""
    n, buf = 128, 256e6
    g0 = T.torus3d(*T.square_dims3(n))
    sched = S.rhd_all_reduce(n, buf)  # 14 rounds
    std = _std(n)
    t0 = time.perf_counter()
    plan(g0, std, sched, HW)
    assert time.perf_counter() - t0 < 1.0


def test_plan_breakdown_sums_to_total():
    n, buf = 16, 1e7
    p = plan(T.grid2d(4, 4), _std(n), S.rhd_reduce_scatter(n, buf), HW)
    b = p.breakdown()
    assert b["total"] == pytest.approx(
        b["alpha"] + b["beta"] + b["dilation"] + b["congestion"] + b["reconfig"]
    )


def test_high_reconfig_cost_falls_back_to_connected_graph():
    """§4.1 'Managing disconnected graphs': with huge r the planner must not
    pay per-round reconfigs; it should pick one (possibly standard) topology
    and stay."""
    n, buf = 16, 1e6
    hw = HW.with_reconfig(10.0)  # absurd 10 s reconfig
    g0 = T.ring(n)
    p = plan(g0, _std(n), S.rhd_reduce_scatter(n, buf), hw)
    assert p.num_reconfigs == 0
    assert p.total_cost == pytest.approx(C.schedule_cost_fixed(g0, p.schedule, hw).total)
