"""Hypothesis property tests on system-level invariants (deliverable c)."""


import pytest
from conftest import hypothesis_or_stubs

# real hypothesis when installed; otherwise only the property tests skip
given, settings, st = hypothesis_or_stubs()

from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.pccl import CollectiveRequest, plan_collective
from repro.core.planner import plan
from repro.core.simulate import verify
from repro.core.schedules import split_for_fanout

HW = cm.H100_DGX


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    buf=st.floats(min_value=1e3, max_value=2e9),
    r=st.floats(min_value=1e-7, max_value=1e-2),
    topo=st.sampled_from(["ring", "torus2d", "grid2d", "grid3d"]),
)
def test_plan_bounded_by_extremes(n, buf, r, topo):
    """Planner cost ∈ [ideal, min(fixed-cost, always-reconfig-cost)]: it can
    never beat contention-free α–β and never lose to its own endpoints."""
    hw = HW.with_reconfig(r)
    g0 = T.standard_topologies(n)[topo]
    sched = S.rhd_reduce_scatter(n, buf)
    std = [T.ring(n)]
    p = plan(g0, std, sched, hw)
    ideal = cm.ideal_cost(sched, hw)
    fixed = cm.schedule_cost_fixed(g0, sched, hw).total
    always = ideal + len(sched.rounds) * r
    assert p.total_cost >= ideal - 1e-15
    assert p.total_cost <= min(fixed, always) + 1e-12


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    b1=st.floats(min_value=1e3, max_value=1e7),
    mult=st.floats(min_value=1.5, max_value=100.0),
)
def test_plan_cost_monotone_in_buffer(n, b1, mult):
    g0 = T.ring(n)
    c1 = plan_collective(CollectiveRequest("reduce_scatter", n, b1), g0, HW).cost
    c2 = plan_collective(CollectiveRequest("reduce_scatter", n, b1 * mult), g0, HW).cost
    assert c2 >= c1 - 1e-15


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8]),
    tx=st.integers(min_value=1, max_value=3),
)
def test_split_for_fanout_preserves_semantics(n, tx):
    """Tx/Rx splitting (§4.2) must not change the collective's outcome."""
    sched = S.dex_all_to_all(n, 64.0)
    split = split_for_fanout(sched, tx)
    verify(split)
    for rnd in split.rounds:
        assert rnd.max_fanout() <= tx


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), buf=st.floats(min_value=1e3, max_value=1e9))
def test_allreduce_equals_two_reduce_scatters(n, buf):
    """Paper §5: AllReduce = RS + mirror AG with equal cost ⇒ exactly 2× RS
    on ideal fabric."""
    rs = cm.ideal_cost(S.rhd_reduce_scatter(n, buf), HW)
    ar = cm.ideal_cost(S.rhd_all_reduce(n, buf), HW)
    assert ar == pytest.approx(2 * rs, rel=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    buf=st.floats(min_value=1e3, max_value=1e9),
)
def test_congestion_dilation_never_negative(n, buf):
    for topo in T.standard_topologies(n).values():
        for rnd in S.rhd_reduce_scatter(n, buf).rounds:
            rc = cm.comm_cost_round(topo, rnd, None, HW)
            if rc.feasible:
                assert rc.dilation >= 1 and rc.congestion >= 1
                assert rc.total >= HW.alpha + HW.beta * rnd.size - 1e-18


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10))
def test_fiber_routing_load_counts_consistent(seed):
    from repro.core.fibers import random_demands, route_fibers, server_grid

    topo = server_grid(16)
    demands = random_demands(topo, 24, seed=seed)
    r = route_fibers(topo, demands)
    load = {}
    for p in r.routes:
        for a, b in zip(p[:-1], p[1:]):
            assert topo.has_edge(a, b)
            load[(a, b)] = load.get((a, b), 0) + 1
    assert max(load.values()) == r.z
