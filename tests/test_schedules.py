import math

import pytest
from conftest import hypothesis_or_stubs

# real hypothesis when installed; otherwise only the property tests skip
given, settings, st = hypothesis_or_stubs()

from repro.core import schedules as S
from repro.core.simulate import verify

POW2 = [2, 4, 8, 16]
ANY_N = [2, 3, 4, 5, 6, 8, 12, 16]


# ------------------------------------------------------------ semantic checks
@pytest.mark.parametrize("n", ANY_N)
def test_ring_reduce_scatter_postcondition(n):
    verify(S.ring_reduce_scatter(n, 1024.0))


@pytest.mark.parametrize("n", ANY_N)
def test_ring_all_gather_postcondition(n):
    verify(S.ring_all_gather(n, 1024.0))


@pytest.mark.parametrize("n", ANY_N)
def test_ring_all_reduce_postcondition(n):
    verify(S.ring_all_reduce(n, 1024.0))


@pytest.mark.parametrize("n", POW2)
def test_rhd_reduce_scatter_postcondition(n):
    verify(S.rhd_reduce_scatter(n, 1024.0))


@pytest.mark.parametrize("n", POW2)
def test_rhd_all_gather_postcondition(n):
    verify(S.rhd_all_gather(n, 1024.0))


@pytest.mark.parametrize("n", POW2)
def test_rhd_all_reduce_postcondition(n):
    verify(S.rhd_all_reduce(n, 1024.0))


@pytest.mark.parametrize("dims", [(2, 2), (2, 4), (4, 4), (2, 2, 2), (2, 4, 4), (4, 4, 4)])
def test_bucket_reduce_scatter_postcondition(dims):
    verify(S.bucket_reduce_scatter(dims, 4096.0))


@pytest.mark.parametrize("dims", [(2, 2), (2, 4), (4, 4), (2, 2, 2), (4, 4, 4)])
def test_bucket_all_gather_postcondition(dims):
    verify(S.bucket_all_gather(dims, 4096.0))


@pytest.mark.parametrize("dims", [(2, 2), (4, 4), (2, 2, 2)])
def test_bucket_all_reduce_postcondition(dims):
    verify(S.bucket_all_reduce(dims, 4096.0))


@pytest.mark.parametrize("n", POW2)
def test_dex_all_to_all_postcondition(n):
    verify(S.dex_all_to_all(n, 1024.0))


@pytest.mark.parametrize("n", ANY_N)
def test_direct_all_to_all_postcondition(n):
    verify(S.direct_all_to_all(n, 1024.0))


@pytest.mark.parametrize("n", ANY_N)
def test_ring_all_to_all_postcondition(n):
    verify(S.ring_all_to_all(n, 1024.0))


def test_p2p_postcondition():
    verify(S.p2p(8, 2, 5, 64.0))


# ------------------------------------------------------------- cost structure
def test_ring_round_counts_and_sizes():
    n, d = 8, 800.0
    rs = S.ring_reduce_scatter(n, d)
    assert rs.num_rounds == n - 1
    assert all(r.size == d / n for r in rs.rounds)
    ar = S.ring_all_reduce(n, d)
    assert ar.num_rounds == 2 * (n - 1)
    # β-optimality: each rank sends 2·d·(n-1)/n
    assert ar.total_bytes_per_rank() == pytest.approx(2 * d * (n - 1) / n)


def test_rhd_round_counts_and_sizes():
    n, d = 8, 800.0
    rs = S.rhd_reduce_scatter(n, d)
    assert rs.num_rounds == int(math.log2(n))
    assert rs.round_sizes() == [d / 2, d / 4, d / 8]
    # same β as ring (bandwidth-optimal)
    assert rs.total_bytes_per_rank() == pytest.approx(d * (n - 1) / n)
    ag = S.rhd_all_gather(n, d)
    assert ag.round_sizes() == [d / 8, d / 4, d / 2]


def test_dex_alpha_optimal_beta_suboptimal():
    n, d = 8, 800.0
    a2a = S.dex_all_to_all(n, d)
    assert a2a.num_rounds == 3
    assert all(r.size == d / 2 for r in a2a.rounds)
    assert a2a.total_bytes_per_rank() == pytest.approx(d / 2 * math.log2(n))
    direct = S.direct_all_to_all(n, d)
    assert direct.num_rounds == n - 1
    assert direct.total_bytes_per_rank() == pytest.approx(d * (n - 1) / n)


def test_swing_distances():
    assert [S.swing_distance(s) for s in range(5)] == [1, -1, 3, -5, 11]
    sw = S.swing_reduce_scatter(16, 1600.0)
    assert sw.num_rounds == 4
    assert sw.round_sizes() == [800.0, 400.0, 200.0, 100.0]


# --------------------------------------------------------- structural invariants
@pytest.mark.parametrize(
    "sched_fn",
    [
        lambda n, d: S.ring_reduce_scatter(n, d),
        lambda n, d: S.rhd_reduce_scatter(n, d),
        lambda n, d: S.rhd_all_gather(n, d),
        lambda n, d: S.swing_reduce_scatter(n, d),
        lambda n, d: S.dex_all_to_all(n, d),
        lambda n, d: S.direct_all_to_all(n, d),
    ],
)
def test_rounds_are_permutations(sched_fn):
    """Every round = one circuit set: each rank has ≤1 Tx and ≤1 Rx (§4.2)."""
    sched = sched_fn(8, 64.0)
    for rnd in sched.rounds:
        assert rnd.is_permutation()


def test_bucket_rounds_are_permutations():
    for rnd in S.bucket_reduce_scatter((4, 4), 64.0).rounds:
        assert rnd.is_permutation()


def test_split_for_fanout():
    # build an artificial round where rank 0 sends to 3 peers
    from repro.core.schedules import Round, Schedule, Transfer

    rnd = Round(
        (
            Transfer(0, 1, (0,)),
            Transfer(0, 2, (1,)),
            Transfer(0, 3, (2,)),
            Transfer(1, 0, (3,)),
        ),
        10.0,
    )
    sched = Schedule("p2p", "x", 4, 10.0, (rnd,))
    split = S.split_for_fanout(sched, tx_limit=1)
    assert split.num_rounds == 3
    for r in split.rounds:
        assert r.max_fanout() <= 1
    # all transfers preserved
    total = sum(len(r.transfers) for r in split.rounds)
    assert total == 4


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.floats(min_value=1.0, max_value=1e9))
def test_property_rhd_all_reduce_correct_any_size(n, d):
    verify(S.rhd_all_reduce(n, d))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=2, max_value=10), st.floats(min_value=1.0, max_value=1e9))
def test_property_ring_all_reduce_correct_any_n(n, d):
    verify(S.ring_all_reduce(n, d))


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([(2, 2), (2, 3), (3, 3), (2, 2, 2), (2, 3, 4)]))
def test_property_bucket_any_dims(dims):
    verify(S.bucket_reduce_scatter(dims, 1024.0))
    verify(S.bucket_all_gather(dims, 1024.0))


def test_get_schedule_registry():
    s = S.get_schedule("all_reduce", "ring", 4, 100.0)
    assert s.algorithm == "ring" and s.collective == "all_reduce"
    s = S.get_schedule("reduce_scatter", "bucket2d", 16, 100.0, dims=(4, 4))
    assert s.n == 16
    with pytest.raises(KeyError):
        S.get_schedule("all_reduce", "nope", 4, 1.0)
    with pytest.raises(ValueError):
        S.get_schedule("reduce_scatter", "bucket2d", 16, 1.0)
