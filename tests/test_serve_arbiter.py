"""Online fabric arbiter (repro.serve.arbiter): admission, SLA shedding,
preemption, fault survival — plus the sectioned EngineConfig validation.

Everything runs in virtual time on the sim/planning path: no devices, no
wall clocks, so every scenario is deterministic."""

import math

import pytest

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.core import topology as T
from repro.runtime.fault import LinkFailure, fail_link
from repro.serve.arbiter import (
    DECODE,
    KV_MIGRATION,
    PREFILL,
    SHED_DEADLINE,
    SHED_QUEUE_FULL,
    ArbiterConfig,
    FabricArbiter,
    SlaTarget,
)
from repro.serve.engine import (
    EngineConfig,
    FabricSection,
    ModelSection,
    RuntimeSection,
)

N = 16


def make_arbiter(**cfg_kwargs) -> FabricArbiter:
    session = PcclSession(cm.H100_DGX, g0=T.ring(N))
    return FabricArbiter(
        session, tp=4, dp=4, d_model=512, cfg=ArbiterConfig(**cfg_kwargs)
    )


# ------------------------------------------------------------- admission
def test_empty_queue_tick_is_noop():
    arb = make_arbiter()
    out = arb.tick()
    assert out["executed"] == 0 and out["round_s"] == 0.0
    assert arb.clock == 0.0 and arb.rounds == 0
    # an idle tick with a future `now` advances the clock but plans nothing
    arb.tick(now=1.5)
    assert arb.clock == 1.5 and arb.rounds == 0
    assert arb.report()["utilization"] == 0.0


def test_all_deadlines_expired_batch_sheds_everything():
    arb = make_arbiter()
    for _ in range(3):
        arb.submit(arb.make_request(DECODE))
    arb.submit(arb.make_request(PREFILL, context_len=256))
    # jump virtual time past every deadline: the whole batch is shed with
    # attributable outcomes, nothing is planned
    out = arb.tick(now=10.0)
    assert out["executed"] == 0 and arb.queue_depth == 0
    shed = [o for o in arb.outcomes if o.status == "shed"]
    assert len(shed) == 4
    assert all(o.reason == SHED_DEADLINE for o in shed)
    assert arb.report()["shed_reasons"][SHED_DEADLINE] == 4


def test_burst_beyond_queue_bound_sheds_with_accounting():
    arb = make_arbiter(queue_bound=4)
    accepted = sum(arb.submit(arb.make_request(DECODE)) for _ in range(10))
    assert accepted == 4 and arb.queue_depth == 4
    rep = arb.report()
    assert rep["shed_reasons"][SHED_QUEUE_FULL] == 6
    assert rep["admitted"] == 4
    # every submission got exactly one outcome or a queue slot
    assert len(arb.outcomes) + arb.queue_depth == 10
    # shedding is deadline-aware: a tighter-deadline newcomer evicts the
    # slackest incumbent instead of being dropped itself
    kv = arb.make_request(KV_MIGRATION, context_len=64)   # slack deadline
    arb2 = make_arbiter(queue_bound=1)
    assert arb2.submit(kv)
    urgent = arb2.make_request(DECODE)                    # tight deadline
    assert arb2.submit(urgent)
    assert arb2.queue_depth == 1
    evicted = [o for o in arb2.outcomes if o.status == "shed"]
    assert [o.rid for o in evicted] == [kv.rid]
    assert evicted[0].reason == SHED_QUEUE_FULL


def test_request_validation():
    arb = make_arbiter()
    with pytest.raises(ValueError, match="kind"):
        arb.make_request("training")
    with pytest.raises(ValueError, match="context_len"):
        arb.make_request(PREFILL, context_len=0)
    with pytest.raises(ValueError, match="tp >= 2"):
        FabricArbiter(PcclSession(cm.H100_DGX), tp=1, dp=4, d_model=64)
    with pytest.raises(ValueError, match="queue_bound"):
        ArbiterConfig(queue_bound=0)


# ------------------------------------------------------------ preemption
def test_preemption_during_fused_dispatch_falls_back():
    """A decode deadline the joint round cannot meet makes decode steal the
    fabric: prefill is preempted back to the queue, the in-flight fused
    dispatch falls back (counted), and the preempted request still
    completes later with its preemption recorded."""
    arb = make_arbiter(
        sla=SlaTarget(prefill_s=10.0, decode_s=1e-7, kv_migration_s=10.0),
        fused_dispatch=True,
    )
    pf = arb.make_request(PREFILL, context_len=512)
    arb.submit(pf)
    arb.submit(arb.make_request(DECODE))
    out = arb.tick()
    assert out["preempted"] is True
    assert out["kinds"] == (DECODE,)
    assert arb.preemptions == 1 and arb.fused_fallbacks == 1
    # prefill went back to the queue, not to an outcome
    assert arb.queue_depth == 1
    done = {o.rid for o in arb.outcomes if o.status == "completed"}
    assert pf.rid not in done
    # next tick (decode pressure gone) completes the preempted prefill
    out2 = arb.tick()
    assert out2["executed"] == 1 and out2["preempted"] is False
    pf_out = [o for o in arb.outcomes if o.rid == pf.rid]
    assert pf_out and pf_out[0].status == "completed"
    assert pf_out[0].preemptions == 1


def test_no_preemption_when_disabled_or_sla_met():
    arb = make_arbiter(preemption=False,
                       sla=SlaTarget(10.0, 1e-7, 10.0))
    arb.submit(arb.make_request(PREFILL, context_len=512))
    arb.submit(arb.make_request(DECODE))
    out = arb.tick()
    assert out["preempted"] is False and out["executed"] == 2
    arb2 = make_arbiter()  # default SLA comfortably above one round
    arb2.submit(arb2.make_request(PREFILL, context_len=512))
    arb2.submit(arb2.make_request(DECODE))
    assert arb2.tick()["preempted"] is False


# ------------------------------------------------------- joint planning
def test_mixed_round_plans_jointly_with_offsets():
    arb = make_arbiter(prefill_lead_rounds=2)
    for _ in range(3):
        arb.submit(arb.make_request(DECODE))
    arb.submit(arb.make_request(PREFILL, context_len=300))
    arb.submit(arb.make_request(KV_MIGRATION, context_len=700))
    out = arb.tick()
    assert out["executed"] == 5
    assert out["kinds"] == (PREFILL, DECODE, KV_MIGRATION)
    assert out["joint_s"] <= out["sequential_s"] * (1 + 1e-12)
    lat = [o.latency_s for o in arb.outcomes if o.status == "completed"]
    assert all(not math.isnan(x) and x > 0 for x in lat)


def test_repeat_shapes_hit_plan_cache():
    """Once the threaded fabric reaches its fixed point, a repeated
    (collective, n, nbytes) admission shape plans in O(1) — pure cache
    hits, the tentpole's serving-loop fast path."""
    arb = make_arbiter()
    for _ in range(3):
        for _ in range(3):
            arb.submit(arb.make_request(DECODE))
        arb.submit(arb.make_request(PREFILL, context_len=300))
        arb.tick()
    hits0, misses0 = arb.session.stats.hits, arb.session.stats.misses
    for _ in range(3):
        arb.submit(arb.make_request(DECODE))
    arb.submit(arb.make_request(PREFILL, context_len=300))
    arb.tick()
    assert arb.session.stats.hits == hits0 + 1
    assert arb.session.stats.misses == misses0


# ------------------------------------------------------------- fault path
def test_replan_under_load_after_fail_link():
    """A mid-stream link failure warm-replans the session; the arbiter
    keeps serving on the degraded fabric with no cold restart."""
    arb = make_arbiter()
    for _ in range(2):
        arb.submit(arb.make_request(DECODE))
    arb.tick()
    failure = fail_link(arb, 0, 1)
    assert isinstance(failure, LinkFailure) and arb.faults == 1
    # the session's fabric permanently lost the link, both directions
    edges = arb.session.fabric(N).edges
    assert (0, 1) not in edges and (1, 0) not in edges
    for _ in range(2):
        arb.submit(arb.make_request(DECODE))
    arb.submit(arb.make_request(PREFILL, context_len=128))
    out = arb.tick()
    assert out["executed"] == 3
    assert out["joint_s"] <= out["sequential_s"] * (1 + 1e-12)


def test_fail_link_on_bare_session():
    sess = PcclSession(cm.H100_DGX, g0=T.ring(8))
    fail_link(sess, 2, 3)
    edges = sess.fabric(8).edges
    assert (2, 3) not in edges and (3, 2) not in edges


# ------------------------------------------------------- EngineConfig split
def test_engine_config_flat_kwargs_back_compat():
    c = EngineConfig(batch_size=2, max_len=32, tp=4, dp=4)
    assert (c.batch_size, c.max_len, c.tp, c.dp, c.greedy) == (2, 32, 4, 4, True)
    assert c.runtime == RuntimeSection(2, 32)
    assert c.fabric == FabricSection(tp=4, dp=4)
    assert c.fabric.n == 16
    assert EngineConfig() == EngineConfig()  # defaults are stable


def test_engine_config_sections_equal_flat():
    flat = EngineConfig(batch_size=2, max_len=32, greedy=False, tp=2, dp=2)
    sectioned = EngineConfig(
        model=ModelSection(greedy=False),
        runtime=RuntimeSection(batch_size=2, max_len=32),
        fabric=FabricSection(tp=2, dp=2),
    )
    assert flat == sectioned and hash(flat) == hash(sectioned)


def test_engine_config_validation_is_attributable():
    with pytest.raises(ValueError, match="batch_size"):
        EngineConfig(batch_size=0)
    with pytest.raises(ValueError, match="KV slots"):
        EngineConfig(batch_size=64, max_len=32)
    with pytest.raises(ValueError, match="mesh_n=16"):
        FabricSection(tp=4, dp=2, mesh_n=16)
    assert FabricSection(tp=4, dp=4, mesh_n=16).n == 16
    with pytest.raises(ValueError, match="not both"):
        EngineConfig(tp=2, fabric=FabricSection(tp=2))
    with pytest.raises(ValueError, match="not both"):
        EngineConfig(greedy=False, model=ModelSection())
    with pytest.raises(ValueError, match="not both"):
        EngineConfig(max_len=64, runtime=RuntimeSection())
