"""Session-API tests: plan cache accounting, fabric-state threading,
Communicator.split semantics, sim backend, and the deprecation shims.

Device-level backend parity (interp vs xla) lives in multidevice_check.py,
which runs under 8 host devices in a subprocess.
"""


import numpy as np
import pytest

from repro.api import PcclSession, get_backend, subgroup_schedule
from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.pccl import CollectiveRequest, plan_collective

HW = cm.H100_DGX
MB = 1024.0 ** 2


# ------------------------------------------------------------------ caching
def test_plan_cache_hit_miss_accounting():
    s = PcclSession(HW, g0=T.ring(16), thread_fabric=False)
    assert s.stats.requests == 0
    p1 = s.plan("reduce_scatter", 4 * MB)
    assert (s.stats.hits, s.stats.misses, s.stats.size) == (0, 1, 1)
    p2 = s.plan("reduce_scatter", 4 * MB)
    assert (s.stats.hits, s.stats.misses, s.stats.size) == (1, 1, 1)
    assert p2 is p1  # the cached object, not a re-plan
    s.plan("reduce_scatter", 8 * MB)           # different nbytes → miss
    s.plan("all_gather", 4 * MB)               # different collective → miss
    s.plan("reduce_scatter", 4 * MB, algorithm="ring")  # different algo → miss
    assert (s.stats.hits, s.stats.misses, s.stats.size) == (1, 4, 4)


def test_cache_key_includes_fabric_fingerprint():
    s = PcclSession(HW, g0=T.grid2d(4, 4), thread_fabric=True)
    s.plan("reduce_scatter", 4 * MB, algorithm="ring")
    # fabric changed (threaded) → same request is a miss, not a stale hit
    s.plan("reduce_scatter", 4 * MB, algorithm="ring")
    assert s.stats.misses == 2
    # fabric is now a fixed point of this plan → third call hits
    s.plan("reduce_scatter", 4 * MB, algorithm="ring")
    assert s.stats.hits == 1


# --------------------------------------------------------------- threading
def test_fabric_threading_lowers_repeated_collective_cost():
    """Second of two identical collectives costs ≤ cold start: the fabric
    already holds the circuits the first one programmed."""
    for algo in ("ring", "rhd"):
        s = PcclSession(HW, g0=T.grid2d(4, 8))
        cold = s.plan("reduce_scatter", 64 * MB, algorithm=algo)
        warm = s.plan("reduce_scatter", 64 * MB, algorithm=algo)
        assert warm.cost <= cold.cost + 1e-15, algo
    # ring's per-round ideal is one topology: warm start saves exactly one
    # reconfiguration relative to cold start off-fabric
    s = PcclSession(HW, g0=T.grid2d(4, 8))
    cold = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    warm = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    assert cold.num_reconfigs == 1 and warm.num_reconfigs == 0
    assert warm.cost == pytest.approx(cold.cost - HW.reconfig_delay)


def test_reset_fabric_restores_cold_start():
    s = PcclSession(HW, g0=T.grid2d(4, 8))
    cold = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    s.reset_fabric()
    assert s.fabric().edges == T.grid2d(4, 8).edges
    again = s.plan("reduce_scatter", 64 * MB, algorithm="ring")
    assert again.cost == pytest.approx(cold.cost)
    assert s.stats.hits >= 1  # cold key re-used from the cache


def test_session_plan_matches_stateless_facade_cold():
    req = CollectiveRequest("reduce_scatter", 32, 64 * MB, algorithm="auto")
    legacy = plan_collective(req, T.ring(32), HW)
    s = PcclSession(HW, g0=T.ring(32), thread_fabric=False)
    new = s.plan("reduce_scatter", 64 * MB, algorithm="auto")
    assert new.cost == pytest.approx(legacy.cost)
    assert new.algorithm == legacy.algorithm


def test_choose_algorithm_parity_with_facade():
    from repro.core.pccl import choose_algorithm

    s = PcclSession(HW, thread_fabric=False)
    assert s.choose_algorithm("all_to_all", 4 * 1024, n=64) == choose_algorithm(
        "all_to_all", 64, 4 * 1024, HW
    )
    assert s.choose_algorithm("all_to_all", 1024 ** 3, n=64) == choose_algorithm(
        "all_to_all", 64, 1024 ** 3, HW
    )


# ------------------------------------------------------------------- split
def test_communicator_split_groups():
    s = PcclSession(cm.TPU_V5E_PHOTONIC)
    root = s.communicator("x", 8)
    tp = root.split([r % 2 for r in range(8)])
    assert tp.n == 4 and tp.axis_size == 8
    assert tp.groups == ((0, 2, 4, 6), (1, 3, 5, 7))
    assert tp.group_of(3) == (1, 3, 5, 7)
    dp = root.split([r // 4 for r in range(8)])
    assert dp.groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    # plans are made for the group size, not the axis size
    assert tp._schedule("all_reduce", 1024).n == 4

    with pytest.raises(ValueError):
        tp.split([0] * 8)  # no re-splitting
    with pytest.raises(ValueError):
        root.split([0, 0, 0, 1, 1, 1, 1, 1])  # unequal groups
    with pytest.raises(ValueError):
        root.split([0, 1])  # wrong length


def test_subgroup_schedule_is_valid_axis_permutation():
    sched = S.ring_all_reduce(4, 1024.0)
    groups = ((0, 2, 4, 6), (1, 3, 5, 7))
    axis_sched = subgroup_schedule(sched, groups, 8)
    assert axis_sched.n == 8
    assert len(axis_sched.rounds) == len(sched.rounds)
    for rnd in axis_sched.rounds:
        assert rnd.is_permutation()
        assert {t.src for t in rnd.transfers} == set(range(8))
        for t in rnd.transfers:  # transfers stay inside one group
            g = 0 if t.src in groups[0] else 1
            assert t.dst in groups[g]
            assert all(c < 4 for c in t.chunks)  # chunk ids stay group-local


# ---------------------------------------------------------------- backends
def test_get_backend_names_and_errors():
    for name in ("xla", "interp", "sim"):
        assert get_backend(name).name == name
    with pytest.raises(ValueError):
        get_backend("nope")


def test_sim_backend_accounting_and_shapes():
    s = PcclSession(HW, thread_fabric=False)
    comm = s.communicator("x", 8, backend="sim")
    x = np.ones((8, 16), np.float32)

    out = comm.all_reduce(x)
    assert out.shape == x.shape
    want = s.plan("all_reduce", x.nbytes, n=8, algorithm="auto").cost
    assert comm.sim_elapsed_s == pytest.approx(want)

    shard = comm.reduce_scatter(np.ones((16, 4), np.float32))
    assert shard.shape == (2, 4)
    gathered = comm.all_gather(np.ones((2, 4), np.float32))
    assert gathered.shape == (16, 4)
    a2a = comm.all_to_all(np.ones((16, 2), np.float32))
    assert a2a.shape == (16, 2)
    assert len(comm.backend.events) == 4
    assert comm.sim_elapsed_s > want  # every collective accumulated


def test_split_shares_stateful_backend_accounting():
    s = PcclSession(HW, thread_fabric=False)
    root = s.communicator("x", 8, backend="sim")
    sub = root.split([r % 2 for r in range(8)])
    assert sub.backend is root.backend  # one account across the hierarchy
    sub.all_reduce(np.ones((4, 8), np.float32))
    assert root.sim_elapsed_s > 0.0 and len(root.backend.events) == 1
    # explicit backend override still gets a fresh instance
    fresh = root.split([r // 4 for r in range(8)], backend="sim")
    assert fresh.backend is not root.backend


def test_sim_backend_serves_engine_comm_report():
    import dataclasses

    from repro.configs import get_config
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    cfg = dataclasses.replace(get_config("chatglm3-6b").reduced(), n_layers=2)
    eng = ServeEngine(cfg, EngineConfig(batch_size=2, max_len=32, tp=4))
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4)
            for _ in range(2)]
    eng.generate(reqs)
    rep = eng.comm_report()
    assert rep["tp"] == 4 and rep["events"] > 0
    assert rep["sim_comm_s"] > 0.0
    assert rep["algorithm"] != "none"


# ------------------------------------------------------------------- shims
def test_pcclcomm_shim_warns_and_delegates():
    from repro.comm.pccl_collectives import PcclComm

    with pytest.warns(DeprecationWarning):
        comm = PcclComm(axis_name="x", n=8)
    assert comm.chosen_algorithm("all_reduce", 64 * 4) in (
        "rhd", "ring", "bucket2d", "bucket3d"
    )
    # legacy semantics: plans stay cold (no fabric threading)
    a1 = comm._schedule("all_reduce", 4 * MB)
    a2 = comm._schedule("all_reduce", 4 * MB)
    assert a1 is a2  # served by the session plan cache
    assert comm._session.thread_fabric is False


def test_plan_collective_shim_warns_and_delegates_bit_identically():
    """The bare free functions remain available until the named removal
    version, warn with the submit() replacement, and return exactly what
    the non-deprecated sweep path returns."""
    import warnings

    from repro.core.pccl import (
        SHIM_REMOVAL_VERSION,
        choose_algorithm,
        plan_collective_sweep,
    )

    req = CollectiveRequest("all_reduce", 16, 4 * MB)
    g0 = T.ring(16)
    with pytest.warns(DeprecationWarning) as rec:
        shimmed = plan_collective(req, g0, HW)
    msg = str(rec[0].message)
    assert SHIM_REMOVAL_VERSION in msg and "submit" in msg
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        direct = plan_collective_sweep(req, [req.buffer_bytes], g0, HW)[0]
    assert shimmed == direct  # bit-identical delegation

    with pytest.warns(DeprecationWarning, match=SHIM_REMOVAL_VERSION):
        algo = choose_algorithm("all_reduce", 16, 4 * MB, HW, g0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        auto = plan_collective_sweep(
            CollectiveRequest("all_reduce", 16, 4 * MB, algorithm="auto"),
            [4 * MB], g0, HW,
        )[0]
    assert algo == auto.algorithm

    from repro.comm.pccl_collectives import PcclComm

    with pytest.warns(DeprecationWarning, match=SHIM_REMOVAL_VERSION):
        PcclComm(axis_name="x", n=8)


# -------------------------------------------------------- submit() surface
def test_submit_parity_with_named_entrypoints():
    """session.submit(Request(...)) must be bit-identical to the named
    method with the same arguments — same results, same cache traffic."""
    from repro.api import (
        ConcurrentCollectiveRequest,
        ConcurrentPlanRequest,
        HierarchicalPlanRequest,
        PlanRequest,
        PlanSweepRequest,
        ReplanRequest,
    )
    from repro.core.schedules import mesh_groups

    a = PcclSession(HW, g0=T.ring(16))
    b = PcclSession(HW, g0=T.ring(16))
    assert a.plan("all_reduce", 4 * MB) == b.submit(
        PlanRequest("all_reduce", 4 * MB)
    )
    assert a.plan_sweep("all_gather", [MB, 8 * MB]) == b.submit(
        PlanSweepRequest("all_gather", (MB, 8 * MB))
    )
    assert a.plan_hierarchical("all_reduce", MB, pod_size=4) == b.submit(
        HierarchicalPlanRequest("all_reduce", MB, pod_size=4)
    )
    tp_groups, dp_groups = mesh_groups(4, 4)
    creqs = (
        ConcurrentCollectiveRequest("all_reduce", 4 * MB, groups=tp_groups),
        ConcurrentCollectiveRequest("all_gather", MB, groups=dp_groups),
    )
    ca = a.plan_concurrent(creqs)
    cb = b.submit(ConcurrentPlanRequest(creqs))
    assert ca.plan == cb.plan and ca.joint_cost == cb.joint_cost
    assert a.replan("all_reduce", 4 * MB, failed_edges=[(0, 1)]) == b.submit(
        ReplanRequest("all_reduce", 4 * MB, failed_edges=((0, 1),))
    )
    # both sessions saw identical cache traffic and fabric threading
    assert (a.stats.hits, a.stats.misses) == (b.stats.hits, b.stats.misses)
    assert a.fabric(16).edges == b.fabric(16).edges


def test_submit_rejects_non_requests():
    s = PcclSession(HW, g0=T.ring(8))
    with pytest.raises(TypeError, match="PlanRequest-family"):
        s.submit({"collective": "all_reduce"})


def test_plan_request_normalization():
    """Requests normalize their fields at construction so equal requests
    hash equal however the caller spelled them."""
    from repro.api import PlanRequest, PlanSweepRequest, ReplanRequest

    assert PlanRequest("all_reduce", 4 * MB, dims=[4, 4]) == PlanRequest(
        "all_reduce", float(4 * MB), dims=(4, 4)
    )
    assert hash(PlanSweepRequest("all_gather", [1, 2])) == hash(
        PlanSweepRequest("all_gather", (1.0, 2.0))
    )
    r = ReplanRequest("all_reduce", MB, failed_edges=[[0, 1]],
                      failed_ranks=[np.int64(3)])
    assert r.failed_edges == ((0, 1),) and r.failed_ranks == (3,)
