"""End-to-end behaviour tests for the full system (assignment deliverable c):
train → checkpoint → restore → serve, with PCCL planning in the loop."""

import dataclasses

import numpy as np

from repro.ckpt.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.core import cost_model as cm
from repro.core.pccl import CollectiveRequest, plan_collective
from repro.core.topology import ring
from repro.data.pipeline import DataConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """A model trained by the Trainer serves tokens through the engine from
    the restored checkpoint — the full lifecycle."""
    cfg = dataclasses.replace(get_config("chatglm3-6b").reduced(), n_layers=2)
    steps = 4
    trainer = Trainer(
        model_cfg=cfg,
        data_cfg=DataConfig(global_batch=2, seq_len=16),
        opt_cfg=OptimizerConfig(lr=1e-3, total_steps=steps, warmup_steps=1),
        trainer_cfg=TrainerConfig(total_steps=steps, ckpt_every=2, log_every=100),
        ckpt_cfg=CheckpointConfig(str(tmp_path), async_write=False),
    )
    out = trainer.run()

    # restore params from the final checkpoint and serve
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
    (params, _), step, _ = mgr.restore((out["params"], out["opt_state"]))
    assert step == steps
    eng = ServeEngine(cfg, EngineConfig(batch_size=2, max_len=24), params=params)
    reqs = [
        Request(prompt=np.arange(8, dtype=np.int32) % cfg.vocab, max_new_tokens=4)
        for _ in range(2)
    ]
    served = eng.generate(reqs)
    assert all(len(r.generated) == 4 for r in served)
    assert all(0 <= t < cfg.vocab for r in served for t in r.generated)


def test_pccl_plans_every_arch_comm_pattern():
    """For each assigned arch, the dominant collective pattern is plannable
    (DESIGN.md §4 applicability table)."""
    hw = cm.TPU_V5E_PHOTONIC
    n = 16
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        grad_bytes = 4.0 * 1e9
        p = plan_collective(
            CollectiveRequest("all_reduce", n, grad_bytes, algorithm="auto"),
            ring(n), hw,
        )
        assert p.cost > 0 and np.isfinite(p.cost)
        if cfg.moe:  # EP AllToAll (paper Fig. 10a)
            a2a_bytes = 2.0 * 4096 * cfg.d_model * cfg.moe.top_k
            p = plan_collective(
                CollectiveRequest("all_to_all", n, a2a_bytes), ring(n), hw
            )
            assert p.algorithm == "dex"
            assert p.num_reconfigs >= 1  # reconfiguration is worth it at µs r


def test_serve_engine_batches_are_isolated():
    """Requests in one batch must not leak into each other (left-padded
    prefill + per-slot decode)."""
    cfg = dataclasses.replace(get_config("chatglm3-6b").reduced(), n_layers=2)
    eng = ServeEngine(cfg, EngineConfig(batch_size=2, max_len=32))
    a = [Request(prompt=np.full(8, 3, np.int32), max_new_tokens=4)]
    out_single = eng.generate(a)[0].generated
    b = [
        Request(prompt=np.full(8, 3, np.int32), max_new_tokens=4),
        Request(prompt=np.full(8, 200, np.int32), max_new_tokens=4),
    ]
    out_batched = eng.generate(b)[0].generated
    assert out_single == out_batched


def test_serve_engine_concurrent_prefill_tp_decode_dp():
    """With dp > 1 replicas on one fabric, comm_report carries the arbiter's
    joint pricing of prefill-TP ∥ decode-DP — never worse than pricing the
    two collectives as if each owned the fabric."""
    cfg = dataclasses.replace(get_config("chatglm3-6b").reduced(), n_layers=2)
    eng = ServeEngine(cfg, EngineConfig(batch_size=2, max_len=32, tp=4, dp=4))
    reqs = [Request(prompt=np.full(8, 3, np.int32), max_new_tokens=2)]
    eng.generate(reqs)
    rep = eng.comm_report()
    assert rep["tp"] == 4
    c = rep["concurrent"]
    assert c["dp"] == 4
    assert c["joint_s"] <= c["sequential_s"] * (1 + 1e-12)
    assert c["speedup"] >= 1.0
    assert len(c["algorithms"]) == 2
    # dp == 1 engines stay on the single-axis report
    eng1 = ServeEngine(cfg, EngineConfig(batch_size=2, max_len=32, tp=4))
    eng1.generate([Request(prompt=np.full(8, 3, np.int32), max_new_tokens=2)])
    assert "concurrent" not in eng1.comm_report()
