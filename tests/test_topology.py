import pytest

from repro.core import topology as T


def test_ring_structure():
    r = T.ring(8)
    assert r.n == 8
    assert r.undirected_link_count() == 8
    assert r.has_edge(0, 1) and r.has_edge(1, 0) and r.has_edge(7, 0)
    assert r.hop_count(0, 4) == 4
    assert r.hop_count(0, 7) == 1
    assert r.is_connected()


def test_line_is_ring_without_wrap():
    l = T.line(8)
    assert not l.has_edge(7, 0)
    assert l.hop_count(0, 7) == 7


def test_torus2d_wraparound_and_diameter():
    t = T.torus2d(4, 4)
    assert t.n == 16
    # each node has degree 4 -> 32 undirected links
    assert t.undirected_link_count() == 32
    assert t.hop_count(0, 3) == 1          # row wrap
    assert t.hop_count(0, 12) == 1         # col wrap
    assert t.hop_count(0, 10) == 4         # (2,2): 2+2


def test_grid2d_no_wraparound():
    g = T.grid2d(4, 4)
    assert g.undirected_link_count() == 24
    assert g.hop_count(0, 3) == 3
    assert g.hop_count(0, 15) == 6


def test_torus3d_and_grid3d():
    t = T.torus3d(2, 2, 2)
    # 2-ary axes have no wrap links added twice (d>2 guard): degree 3 each
    assert t.undirected_link_count() == 12
    g = T.grid3d(4, 4, 4)
    assert g.n == 64
    assert g.hop_count(0, 63) == 9


def test_hypercube():
    h = T.hypercube(8)
    assert h.undirected_link_count() == 12
    assert h.hop_count(0, 7) == 3
    with pytest.raises(ValueError):
        T.hypercube(6)


def test_from_transfers_ideal_graph():
    i = T.from_transfers(4, [(0, 1), (2, 3)])
    assert i.has_edge(0, 1) and not i.has_edge(1, 0)
    assert not i.is_connected()
    assert i.hop_count(0, 3) >= 10 ** 9


def test_shortest_path_returns_none_when_disconnected():
    i = T.from_transfers(4, [(0, 1)])
    assert i.shortest_path(2, 3) is None
    assert i.shortest_path(0, 1) == [0, 1]


def test_square_dims():
    assert T.square_dims2(128) == (8, 16)
    assert T.square_dims2(64) == (8, 8)
    a, b, c = T.square_dims3(64)
    assert a * b * c == 64 and (a, b, c) == (4, 4, 4)
    a, b, c = T.square_dims3(128)
    assert a * b * c == 128


def test_standard_topologies_128():
    std = T.standard_topologies(128)
    assert set(std) == {"ring", "torus2d", "torus3d", "grid2d", "grid3d", "hypercube"}
    for t in std.values():
        assert t.n == 128
        assert t.is_connected()


def test_degree_helpers():
    r = T.ring(4)
    assert r.out_degree(0) == 2
    assert r.in_degree(0) == 2
