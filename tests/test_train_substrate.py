"""Training substrate tests: optimizer, pipeline, checkpoint, fault
tolerance, trainer restart semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.runtime.fault import FailureInjector, StragglerConfig, StragglerDetector
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    learning_rate,
)
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200,
                          schedule="constant")
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(cfg, g, params, state)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 100


def test_lr_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(learning_rate(cfg, jnp.asarray(0))) == 0.0
    assert float(learning_rate(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(learning_rate(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_grad_clip_applied():
    cfg = OptimizerConfig(lr=0.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, m = adamw_update(cfg, g, params, state)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


# ----------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_host_sharded():
    cfg = get_config("chatglm3-6b").reduced()
    d = DataConfig(global_batch=8, seq_len=16, n_hosts=4, seed=7)
    ds = SyntheticLMData(cfg, d)
    a = ds.global_batch(5)
    b = ds.global_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host shards are disjoint slices of the global batch
    h0 = ds.host_batch(5, host_id=0)
    np.testing.assert_array_equal(a["tokens"][:2], h0["tokens"])
    # different steps differ
    c = ds.global_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab


def test_pipeline_prefetch_iterator():
    cfg = get_config("chatglm3-6b").reduced()
    ds = SyntheticLMData(cfg, DataConfig(global_batch=2, seq_len=8))
    it = ds.iterate(start_step=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.global_batch(3)["tokens"])


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=2, async_write=False))
    tree = {"a": jnp.arange(5, dtype=jnp.float32), "b": {"c": jnp.ones((2, 3))}}
    for s in [10, 20, 30]:
        mgr.save(s, jax.tree.map(lambda x: x + s, tree), extra={"s": s})
    assert mgr.steps() == [20, 30]  # keep=2 GC
    restored, step, extra = mgr.restore(tree)
    assert step == 30 and extra == {"s": 30}
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(5) + 30)


def test_checkpoint_async_and_commit_marker(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_write=True))
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1
    # un-committed directories are ignored
    bad = tmp_path / "step_000000099"
    bad.mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_write=False))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((5,))})


# --------------------------------------------------------------- stragglers
def test_straggler_detection_and_rebalance():
    det = StragglerDetector(StragglerConfig(window=10, threshold=2.0, min_samples=3), 4)
    for _ in range(5):
        for h, t in enumerate([1.0, 1.1, 0.9, 3.5]):
            det.record(h, t)
    assert det.stragglers() == [3]
    alloc = det.rebalance_grains(100)
    assert sum(alloc.values()) == 100
    assert alloc[3] < alloc[0]  # slow host gets fewer grains


# ------------------------------------------------------------------ trainer
def _mini_trainer(tmp_path, fail_at=(), steps=8, arch="chatglm3-6b"):
    cfg = dataclasses.replace(get_config(arch).reduced(), n_layers=2)
    return Trainer(
        model_cfg=cfg,
        data_cfg=DataConfig(global_batch=2, seq_len=16),
        opt_cfg=OptimizerConfig(lr=1e-3, total_steps=steps, warmup_steps=1),
        trainer_cfg=TrainerConfig(total_steps=steps, ckpt_every=2, log_every=100),
        ckpt_cfg=CheckpointConfig(str(tmp_path), keep=3, async_write=False),
        failure_injector=FailureInjector(fail_at_steps=fail_at),
    )


def test_trainer_runs_and_loss_decreases(tmp_path):
    t = _mini_trainer(tmp_path, steps=8)
    out = t.run()
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.05
    assert out["grad_allreduce_algorithm"] in ("none", "ring", "rhd")


def test_trainer_survives_injected_failure_and_resumes(tmp_path):
    t = _mini_trainer(tmp_path, fail_at=(5,), steps=8)
    out = t.run()  # must not raise: restart from step-4 checkpoint
    steps_seen = [h["step"] for h in out["history"]]
    assert steps_seen.count(5) >= 1  # step 5 was replayed after restart
    assert t.ckpt.latest_step() == 8


def test_restart_determinism_matches_uninterrupted(tmp_path):
    """Checkpoint-restart must reproduce the uninterrupted run exactly
    (deterministic data stream + exact state restore)."""
    t1 = _mini_trainer(tmp_path / "a", steps=6)
    out1 = t1.run()
    t2 = _mini_trainer(tmp_path / "b", fail_at=(3,), steps=6)
    out2 = t2.run()
    l1 = {h["step"]: h["loss"] for h in out1["history"]}
    l2 = {h["step"]: h["loss"] for h in out2["history"]}
    # compare the final steps (post-restart path must converge to same values)
    assert l1[5] == pytest.approx(l2[5], rel=1e-5)
