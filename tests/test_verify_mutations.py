"""Mutation testing of the static dataflow verifier.

A verifier that accepts everything is worthless, so we measure its
*kill rate*: corrupt correct schedules with small structural mutations
(drop a transfer, swap a peer, duplicate a reduce contribution, reorder
rounds, flip a reduce flag, relabel a chunk, drop a round) and require
that the verifier rejects >= 95% of the non-identical mutants.

Survivor triage
---------------
Some mutants are *equivalent* at the dataflow level and a dataflow
verifier must not flag them.  Two classes appear in practice:

* **reordering independent rounds** — direct all-to-all rounds commute
  (every transfer ships a distinct chunk straight to its destination);
* **on-path peer swaps** — diverting a ring reduce-scatter transfer to
  a rank farther along the same chunk's ring path keeps the reduction
  correct but breaks round permutation validity.

Every survivor must therefore either pass the dynamic mask oracle
(``core.simulate.verify``) or be caught by the round-feasibility
invariant checker — a survivor neither can account for is a verifier
bug and fails the test explicitly, not just via the kill-rate bar.
"""

import random

from repro.analysis.invariants import check_round_feasibility
from repro.analysis.verify import verify_schedule
from repro.core import schedules as S
from repro.core.schedules import Round, Schedule, Transfer
from repro.core.simulate import SimulationError
from repro.core.simulate import verify as oracle_verify

from conftest import hypothesis_or_stubs

D = 1 << 20

BASES = [
    S.ring_reduce_scatter(8, D),
    S.ring_all_gather(8, D),
    S.ring_all_reduce(4, D),
    S.rhd_reduce_scatter(8, D),
    S.rhd_all_reduce(4, D),
    S.dex_all_to_all(8, D),
    S.direct_all_to_all(8, D),
    S.bucket_reduce_scatter((2, 4), D),
]


def _rebuild(base, rounds):
    rounds = tuple(r for r in rounds if r.transfers)
    return Schedule(base.collective, base.algorithm, base.n,
                    base.buffer_bytes, rounds)


def _pick(rng, sched):
    """(round_index, transfer_index) of a random transfer."""
    ri = rng.randrange(len(sched.rounds))
    return ri, rng.randrange(len(sched.rounds[ri].transfers))


# ------------------------------------------------------------- operators


def mut_drop_transfer(rng, sched):
    ri, ti = _pick(rng, sched)
    rounds = list(sched.rounds)
    tf = rounds[ri].transfers
    rounds[ri] = Round(tf[:ti] + tf[ti + 1:], rounds[ri].size)
    return _rebuild(sched, rounds)


def mut_swap_peer(rng, sched):
    ri, ti = _pick(rng, sched)
    rounds = list(sched.rounds)
    tf = list(rounds[ri].transfers)
    t = tf[ti]
    new_dst = rng.choice([r for r in range(sched.n) if r not in (t.src, t.dst)])
    tf[ti] = Transfer(t.src, new_dst, t.chunks, t.reduce)
    rounds[ri] = Round(tuple(tf), rounds[ri].size)
    return _rebuild(sched, rounds)


def mut_dup_contribution(rng, sched):
    ri, ti = _pick(rng, sched)
    rounds = list(sched.rounds)
    tf = rounds[ri].transfers
    rounds[ri] = Round(tf + (tf[ti],), rounds[ri].size)
    return _rebuild(sched, rounds)


def mut_reorder_rounds(rng, sched):
    if len(sched.rounds) < 2:
        return sched
    i = rng.randrange(len(sched.rounds) - 1)
    rounds = list(sched.rounds)
    rounds[i], rounds[i + 1] = rounds[i + 1], rounds[i]
    return _rebuild(sched, rounds)


def mut_flip_reduce(rng, sched):
    ri, ti = _pick(rng, sched)
    rounds = list(sched.rounds)
    tf = list(rounds[ri].transfers)
    t = tf[ti]
    tf[ti] = Transfer(t.src, t.dst, t.chunks, not t.reduce)
    rounds[ri] = Round(tuple(tf), rounds[ri].size)
    return _rebuild(sched, rounds)


def mut_chunk_relabel(rng, sched):
    ri, ti = _pick(rng, sched)
    rounds = list(sched.rounds)
    tf = list(rounds[ri].transfers)
    t = tf[ti]
    if not t.chunks:
        return sched
    n_chunks = max(c for rnd in sched.rounds for x in rnd.transfers
                   for c in x.chunks) + 1
    chunks = list(t.chunks)
    ci = rng.randrange(len(chunks))
    chunks[ci] = (chunks[ci] + 1 + rng.randrange(n_chunks - 1)) % n_chunks
    tf[ti] = Transfer(t.src, t.dst, tuple(dict.fromkeys(chunks)), t.reduce)
    rounds[ri] = Round(tuple(tf), rounds[ri].size)
    return _rebuild(sched, rounds)


def mut_drop_round(rng, sched):
    if len(sched.rounds) < 2:
        return sched
    i = rng.randrange(len(sched.rounds))
    return _rebuild(sched, sched.rounds[:i] + sched.rounds[i + 1:])


OPERATORS = [mut_drop_transfer, mut_swap_peer, mut_dup_contribution,
             mut_reorder_rounds, mut_flip_reduce, mut_chunk_relabel,
             mut_drop_round]


def _gen_mutants(seed=20260807, per_pair=4):
    """Deterministic corpus: per_pair mutants per (base, operator) pair,
    skipping mutants whose fingerprint matches the base (no-op mutation)."""
    rng = random.Random(seed)
    mutants = []
    for base in BASES:
        fp = base.fingerprint()
        for op in OPERATORS:
            for _ in range(per_pair):
                m = op(rng, base)
                if m.fingerprint() != fp:
                    mutants.append((base, op.__name__, m))
    return mutants


def _oracle_accepts(sched):
    try:
        oracle_verify(sched)
        return True
    except (SimulationError, AssertionError):
        return False


def test_mutation_kill_rate():
    """Kill-rate bar with explicit survivor triage.

    A mutant is *killed* when the dataflow verifier flags it, or when it
    is dataflow-equivalent (oracle accepts) AND the round-feasibility
    checker flags it as inexecutable — the two static passes together
    form the gate that CI runs.  Mutants that are *fully* equivalent
    (oracle accepts AND rounds stay feasible — e.g. reordering the
    commuting rounds of a direct all-to-all yields an equally valid
    schedule) are excluded from the denominator, as is standard in
    mutation testing.  Any survivor outside these classes is a verifier
    hole and fails outright.
    """
    mutants = _gen_mutants()
    assert len(mutants) >= 150  # the corpus is not degenerate

    killed_dataflow = 0   # verifier flagged
    killed_feasibility = []  # equivalent dataflow, inexecutable rounds
    true_equivalents = []    # equally valid schedule; excluded
    unexplained = []         # verifier hole
    for base, op_name, m in mutants:
        if not verify_schedule(m).ok:
            killed_dataflow += 1
        elif check_round_feasibility(m):
            killed_feasibility.append((base.algorithm, op_name))
            # if it survives dataflow, it must at least be dataflow-valid
            assert _oracle_accepts(m), (base.algorithm, op_name)
        elif _oracle_accepts(m):
            true_equivalents.append((base.algorithm, op_name))
        else:
            unexplained.append((base.algorithm, base.collective, op_name))

    assert not unexplained, f"unexplained survivors: {unexplained}"
    # the only fully-equivalent class in this corpus is the commuting
    # direct-a2a round reorder; anything new here needs a docstring entry
    assert all(alg == "direct" and op == "mut_reorder_rounds"
               for alg, op in true_equivalents), true_equivalents

    denom = len(mutants) - len(true_equivalents)
    killed = killed_dataflow + len(killed_feasibility)
    rate = killed / denom
    assert rate >= 0.95, (
        f"kill rate {rate:.3f} ({killed}/{denom}); "
        f"feasibility-only kills: {killed_feasibility}"
    )
    # the dataflow verifier alone must still do the overwhelming majority
    assert killed_dataflow / denom >= 0.85


def test_known_equivalent_mutants_are_triagable():
    """The two equivalence classes from the module docstring, pinned so a
    future verifier change that starts flagging them is caught."""
    # direct all-to-all rounds commute
    base = S.direct_all_to_all(8, D)
    rounds = list(base.rounds)
    rounds[0], rounds[1] = rounds[1], rounds[0]
    reordered = _rebuild(base, rounds)
    assert verify_schedule(reordered).ok
    assert _oracle_accepts(reordered)

    # on-path swap in ring reduce-scatter: divert 4->5 to 4->7; rank 7 is
    # downstream on chunk 5's ring path, so the reduction still completes,
    # but the round is no longer a permutation.
    base = S.ring_reduce_scatter(8, D)
    t0 = base.rounds[0].transfers
    diverted = []
    for t in t0:
        if t.src == 4:
            diverted.append(Transfer(4, 7, t.chunks, t.reduce))
        elif t.src == 6:  # drop 6->7's slot conflict by retargeting its store
            diverted.append(t)
        else:
            diverted.append(t)
    # Only assert the triage property: IF such a mutant survives dataflow
    # verification, feasibility must catch it.
    mutated = _rebuild(base, [Round(tuple(diverted), base.rounds[0].size)]
                       + list(base.rounds[1:]))
    if verify_schedule(mutated).ok:
        assert check_round_feasibility(mutated)


# ------------------------------------------------- property-based (optional)

given, settings, st = hypothesis_or_stubs()


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_random_mutations_never_accepted_silently(seed):
    """For arbitrary seeds: every mutant is either killed by the verifier,
    dataflow-equivalent, or round-infeasible.  (Weaker than the 95% bar —
    this is the no-unexplained-survivor property under fresh randomness.)"""
    rng = random.Random(seed)
    base = BASES[rng.randrange(len(BASES))]
    op = OPERATORS[rng.randrange(len(OPERATORS))]
    m = op(rng, base)
    if m.fingerprint() == base.fingerprint():
        return
    if verify_schedule(m).ok:
        assert _oracle_accepts(m) or check_round_feasibility(m), (
            f"unexplained survivor: {base.algorithm}/{base.collective} "
            f"via {op.__name__} seed={seed}"
        )
