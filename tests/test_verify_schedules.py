"""Static dataflow verification of every built-in schedule generator.

The parametrized sweep proves each generator's collective postcondition
across n ∈ {2, 4, 8, 16} plus non-power-of-two sizes where the algorithm
supports them, and covers the ``split_for_fanout`` / ``replicate_groups``
compositions.  This is the static counterpart of the simulator-based
property tests in ``test_schedules.py`` — and strictly stronger: the
verifier also rejects double-counted reduce contributions and stale-slot
adds that mask-union semantics cannot see (``test_verify_mutations.py``).
"""

import pytest

from repro.analysis.verify import (
    UnverifiableScheduleError,
    assert_verified,
    verify_schedule,
)
from repro.core import schedules as S

D = 1 << 20

POW2 = (2, 4, 8, 16)
ANY_N = (2, 3, 4, 6, 8, 12, 16)
TORUS_DIMS = ((2, 2), (2, 3), (2, 4), (3, 3), (4, 2), (2, 2, 2), (4, 4), (2, 3, 4))


@pytest.mark.parametrize("n", ANY_N)
@pytest.mark.parametrize(
    "gen",
    [S.ring_reduce_scatter, S.ring_all_gather, S.ring_all_reduce,
     S.direct_all_to_all, S.ring_all_to_all],
    ids=lambda f: f.__name__,
)
def test_ring_family_verified(gen, n, dataflow_verifier):
    res = dataflow_verifier(gen(n, D))
    assert res.ok and res.verifiable


@pytest.mark.parametrize("n", POW2)
@pytest.mark.parametrize(
    "gen",
    [S.rhd_reduce_scatter, S.rhd_all_gather, S.rhd_all_reduce, S.dex_all_to_all],
    ids=lambda f: f.__name__,
)
def test_pow2_family_verified(gen, n, dataflow_verifier):
    res = dataflow_verifier(gen(n, D))
    assert res.ok
    assert res.rounds_checked == gen(n, D).num_rounds


@pytest.mark.parametrize("dims", TORUS_DIMS, ids=str)
@pytest.mark.parametrize(
    "gen",
    [S.bucket_reduce_scatter, S.bucket_all_gather, S.bucket_all_reduce],
    ids=lambda f: f.__name__,
)
def test_bucket_family_verified(gen, dims, dataflow_verifier):
    assert dataflow_verifier(gen(dims, D)).ok


@pytest.mark.parametrize("n,src,dst", [(2, 0, 1), (4, 1, 3), (8, 7, 0)])
def test_p2p_verified(n, src, dst, dataflow_verifier):
    assert dataflow_verifier(S.p2p(n, src, dst, D)).ok


# ------------------------------------------------------------- compositions


@pytest.mark.parametrize("tx", (1, 2))
@pytest.mark.parametrize("n", (4, 8, 16))
def test_split_for_fanout_preserves_dataflow(n, tx, dataflow_verifier):
    """Merging rounds raises fan-out; split_for_fanout must restore a
    verifiable schedule without changing the dataflow."""
    base = S.direct_all_to_all(n, D)
    merged = S.Schedule(
        base.collective, base.algorithm, base.n, base.buffer_bytes,
        (S.Round(base.rounds[0].transfers + base.rounds[1].transfers,
                 base.rounds[0].size),) + base.rounds[2:],
    )
    assert dataflow_verifier(merged).ok  # fan-out 2 is still correct dataflow
    split = S.split_for_fanout(merged, tx)
    assert dataflow_verifier(split).ok
    assert all(r.max_fanout() <= tx for r in split.rounds)


@pytest.mark.parametrize("tp,dp", [(2, 2), (4, 2), (2, 4), (4, 4)])
def test_replicate_groups_verified(tp, dp, dataflow_verifier):
    n = tp * dp
    tp_groups, dp_groups = S.mesh_groups(tp, dp)
    rep_tp = S.replicate_groups(S.ring_all_reduce(tp, D), tp_groups, n)
    rep_dp = S.replicate_groups(S.rhd_reduce_scatter(dp, D), dp_groups, n)
    assert dataflow_verifier(rep_tp, groups=tp_groups).ok
    assert dataflow_verifier(rep_dp, groups=dp_groups).ok


def test_replicate_groups_wrong_axis_caught():
    tp_groups, dp_groups = S.mesh_groups(4, 2)
    rep = S.replicate_groups(S.ring_all_reduce(4, D), tp_groups, 8)
    res = verify_schedule(rep, groups=dp_groups)
    assert not res.ok
    assert any(v.kind == "cross-group-transfer" for v in res.violations)


# -------------------------------------------------------------- edge cases


@pytest.mark.parametrize("n", POW2[1:])
def test_swing_is_unverifiable_not_vacuously_correct(n):
    """Swing models only the (src, dst, w) pattern — no chunk metadata.
    The verifier must refuse rather than pass vacuously."""
    for sched in (S.swing_reduce_scatter(n, D), S.swing_all_reduce(n, D)):
        res = verify_schedule(sched)
        assert not res.verifiable and not res.ok
        with pytest.raises(UnverifiableScheduleError):
            assert_verified(sched)


def test_violations_are_attributable():
    """A corrupted schedule yields (round, rank, chunk, expected, actual)."""
    base = S.ring_reduce_scatter(8, D)
    rounds = list(base.rounds)
    rounds[3] = S.Round(rounds[3].transfers[:-1], rounds[3].size)
    res = verify_schedule(S.Schedule(base.collective, base.algorithm, base.n,
                                     base.buffer_bytes, tuple(rounds)))
    assert not res.ok
    v = res.violations[0]
    assert v.kind in ("send-absent", "stale-slot-reduce", "postcondition")
    assert v.rank is not None and v.chunk is not None
    assert v.expected and v.actual
    # stringification carries the full attribution for error messages
    assert str(v.chunk) in str(v) and v.kind in str(v)


def test_verifier_matches_simulator_on_generators():
    """On metadata-carrying generators the static verifier and the dynamic
    oracle must agree (the verifier is strictly stronger only on schedules
    the oracle wrongly accepts — see the mutation suite)."""
    from repro.core.simulate import verify as oracle_verify

    for sched in (S.ring_all_reduce(6, D), S.rhd_all_reduce(8, D),
                  S.dex_all_to_all(8, D), S.bucket_all_reduce((2, 3), D)):
        oracle_verify(sched)  # oracle accepts
        assert verify_schedule(sched).ok  # verifier agrees
